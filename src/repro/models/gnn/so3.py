"""Real SO(3) representation machinery for eSCN-style equivariant models.

``wigner_d_stack`` computes the real Wigner rotation matrices D^l(R) for
l = 0..l_max from batched 3x3 rotation matrices via the Ivanic-Ruedenberg
recursion (J. Phys. Chem. 1996 + errata) — pure arithmetic, jnp-traceable,
unrolled over the (static) l, m, m' grid.  Convention: real spherical
harmonics ordered m = -l..l with the l=1 basis ordered (y, z, x), so that

    Y_l(R n) = D^l(R) Y_l(n)

— the property the unit tests assert against scipy spherical harmonics
for random rotations up to l_max=6.

``rot_to_z`` builds the rotation aligning an edge direction with +z; in
that frame the edge's own SH embedding collapses onto m=0, which is what
makes the eSCN SO(2) convolution O(L^3) instead of O(L^6).
"""

from __future__ import annotations

from functools import partial
from typing import List

import numpy as np

import jax
import jax.numpy as jnp


def rot_to_z(d: jnp.ndarray) -> jnp.ndarray:
    """(E, 3) unit vectors -> (E, 3, 3) rotations R with R d = +z."""
    x, y, z = d[:, 0], d[:, 1], d[:, 2]
    alpha = jnp.arctan2(y, x)
    beta = jnp.arctan2(jnp.sqrt(x * x + y * y), z)
    ca, sa = jnp.cos(alpha), jnp.sin(alpha)
    cb, sb = jnp.cos(beta), jnp.sin(beta)
    # R = Ry(-beta) @ Rz(-alpha)
    rz = jnp.stack([
        jnp.stack([ca, sa, jnp.zeros_like(ca)], -1),
        jnp.stack([-sa, ca, jnp.zeros_like(ca)], -1),
        jnp.stack([jnp.zeros_like(ca), jnp.zeros_like(ca),
                   jnp.ones_like(ca)], -1),
    ], -2)
    ry = jnp.stack([
        jnp.stack([cb, jnp.zeros_like(ca), -sb], -1),
        jnp.stack([jnp.zeros_like(ca), jnp.ones_like(ca),
                   jnp.zeros_like(ca)], -1),
        jnp.stack([sb, jnp.zeros_like(ca), cb], -1),
    ], -2)
    return ry @ rz


def _r1_from_rot(rot: jnp.ndarray) -> jnp.ndarray:
    """Cartesian (x,y,z) rotation -> l=1 real-SH basis (y,z,x) rotation."""
    P = jnp.asarray(
        [[0.0, 1.0, 0.0], [0.0, 0.0, 1.0], [1.0, 0.0, 0.0]], rot.dtype
    )
    return P @ rot @ P.T


def wigner_d_stack(rot: jnp.ndarray, l_max: int) -> List[jnp.ndarray]:
    """(..., 3, 3) rotations -> [D^0 (...,1,1), D^1 (...,3,3), ...].

    Unrolled Ivanic-Ruedenberg recursion; all index arithmetic is static.
    """
    batch = rot.shape[:-2]
    d0 = jnp.ones(batch + (1, 1), rot.dtype)
    out = [d0]
    if l_max == 0:
        return out
    r1 = _r1_from_rot(rot)
    out.append(r1)

    def R1(i, j):
        # i, j in {-1, 0, 1}
        return r1[..., i + 1, j + 1]

    prev = r1
    for l in range(2, l_max + 1):
        def Rp(mu, m_):  # previous-level entry with m indices
            return prev[..., mu + (l - 1), m_ + (l - 1)]

        def Pfn(i, mu, m_):
            if m_ == l:
                return R1(i, 1) * Rp(mu, l - 1) - R1(i, -1) * Rp(mu, -(l - 1))
            if m_ == -l:
                return R1(i, 1) * Rp(mu, -(l - 1)) + R1(i, -1) * Rp(mu, l - 1)
            return R1(i, 0) * Rp(mu, m_)

        rows = []
        for m in range(-l, l + 1):
            cols = []
            for mp in range(-l, l + 1):
                denom = (
                    (l + mp) * (l - mp) if abs(mp) < l else (2 * l) * (2 * l - 1)
                )
                u2 = (l + m) * (l - m) / denom
                d_m0 = 1.0 if m == 0 else 0.0
                v2 = (1.0 + d_m0) * (l + abs(m) - 1) * (l + abs(m)) / denom
                w2 = (l - abs(m) - 1) * (l - abs(m)) / denom
                u = np.sqrt(u2)
                v = 0.5 * np.sqrt(v2) * (1.0 - 2.0 * d_m0)
                w = -0.5 * np.sqrt(w2) * (1.0 - d_m0)
                term = 0.0
                if u != 0.0:
                    if m == 0:
                        U = Pfn(0, 0, mp)
                    else:
                        U = Pfn(0, m, mp)
                    term = term + u * U
                if v != 0.0:
                    if m == 0:
                        V = Pfn(1, 1, mp) + Pfn(-1, -1, mp)
                    elif m > 0:
                        V = Pfn(1, m - 1, mp) * np.sqrt(1.0 + (1.0 if m == 1 else 0.0)) \
                            - Pfn(-1, -m + 1, mp) * (0.0 if m == 1 else 1.0)
                    else:
                        V = Pfn(1, m + 1, mp) * (0.0 if m == -1 else 1.0) \
                            + Pfn(-1, -m - 1, mp) * np.sqrt(1.0 + (1.0 if m == -1 else 0.0))
                    term = term + v * V
                if w != 0.0:
                    if m > 0:
                        W = Pfn(1, m + 1, mp) + Pfn(-1, -m - 1, mp)
                    elif m < 0:
                        W = Pfn(1, m - 1, mp) - Pfn(-1, -m + 1, mp)
                    else:
                        W = None
                    if W is not None:
                        term = term + w * W
                cols.append(term)
            rows.append(jnp.stack(cols, axis=-1))
        cur = jnp.stack(rows, axis=-2)
        out.append(cur)
        prev = cur
    return out


# --------------------------------------------------------------------------
# real spherical harmonics (host/test oracle)
# --------------------------------------------------------------------------

def real_sph_harm_np(l_max: int, dirs: np.ndarray) -> List[np.ndarray]:
    """Orthonormal real SH evaluated at unit vectors (host oracle for the
    Wigner tests); returns [(N, 2l+1)] ordered m=-l..l."""
    try:
        from scipy.special import sph_harm_y  # (l, m, theta, phi); scipy>=1.15
    except ImportError:
        from scipy.special import sph_harm  # (m, l, azimuth, polar)

        def sph_harm_y(l, m, theta, phi):
            return sph_harm(m, l, phi, theta)

    dirs = np.asarray(dirs, dtype=np.float64)
    theta = np.arccos(np.clip(dirs[:, 2], -1, 1))       # polar
    phi = np.arctan2(dirs[:, 1], dirs[:, 0])            # azimuth
    out = []
    for l in range(l_max + 1):
        cols = []
        for m in range(-l, l + 1):
            am = abs(m)
            ylm = sph_harm_y(l, am, theta, phi)         # complex
            if m > 0:
                v = np.sqrt(2.0) * (-1.0) ** m * ylm.real
            elif m < 0:
                v = np.sqrt(2.0) * (-1.0) ** m * ylm.imag
            else:
                v = ylm.real
            cols.append(v)
        out.append(np.stack(cols, axis=1))
    return out
