"""din [recsys]: embed_dim=18 seq_len=100 attn MLP 80-40 MLP 200-80,
target attention. [arXiv:1706.06978]"""
from ..models.recsys.din import DINConfig
from .base import ArchSpec, recsys_cells

NAME = "din"


def make_config(reduced: bool = False) -> DINConfig:
    if reduced:
        return DINConfig(n_items=1000, n_cates=20, seq_len=16)
    return DINConfig(n_items=1_000_000, n_cates=1_000, embed_dim=18,
                     seq_len=100, attn_hidden=(80, 40),
                     mlp_hidden=(200, 80))


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="recsys", make_config=make_config,
        cells=recsys_cells(NAME, make_config),
        notes="embedding lookup is the hot path: tables row-sharded over "
              "the model axis; history pooling uses the segment_bag "
              "substrate",
    )
