"""CI gate: a chaos-driven SLO burn freezes a replayable flight bundle.

The flight recorder's promise is end-to-end: when a burn-rate monitor
fires mid-serve, the frozen bundle must be **self-contained** (all six
artifacts present) and **causally complete** — every request in the
window's p99 latency bucket, reached either through the manifest's
worst-trace table or through the queue-wait histogram's p99 exemplars,
must resolve to a full causal chain (admission record → engine/kernel
spans carrying its trace id → retries/degradation events → completion
status).  This bench stages exactly that incident and asserts all of
it, exiting nonzero on any gap:

1. serve a clean warm phase through ``ResilientEngine`` + ``Frontend``
   with tracing on and the recorder armed (SLO monitor ticking on the
   time-series cadence, short windows so CI stays fast);
2. inject deterministic device faults (raises → retries → exact host
   degradation) until the ``degraded`` burn rate fires;
3. assert a burn-triggered bundle exists, replays through
   :func:`repro.obs.flight.replay` with every worst trace complete,
   and that the CLI (``python -m repro.obs.flight <bundle>``) agrees.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro import obs
from repro.cluster import Frontend
from repro.core import QueryEngine, build_2dreach, make_graph
from repro.obs import flight as obs_flight
from repro.resilience import ResilientEngine
from repro.resilience.faults import FaultPlan, FaultSpec, inject
from repro.resilience.retry import RetryPolicy

BUNDLE_FILES = ("manifest.json", "trace.json", "spans.jsonl",
                "querylog.jsonl", "events.jsonl", "metrics.json")


def _graph(n=400, m=1200, seed=0):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(m, 2))
    spatial = rng.random(n) < 0.4
    coords = (rng.random((n, 2)) * 100).astype(np.float32)
    return make_graph(n, edges, coords, spatial)


def _queries(g, n_q, seed=1):
    rng = np.random.default_rng(seed)
    us = rng.integers(0, g.n_nodes, size=n_q)
    lo = rng.random((n_q, 2)).astype(np.float32) * 70
    return us, np.hstack([lo, lo + 30]).astype(np.float32)


def _drive(fe, us, rects):
    futs = [fe.submit(int(u), r) for u, r in zip(us, rects)]
    fe.flush(timeout=60)
    return [f.result(timeout=60) for f in futs]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output root (default: a fresh tempdir); CI "
                         "passes results/chaos_flight so the bundle "
                         "uploads as an artifact")
    args = ap.parse_args()
    out_dir = args.out or tempfile.mkdtemp(prefix="chaos-flight-")
    os.makedirs(out_dir, exist_ok=True)
    dump_dir = os.path.join(out_dir, "flightdump")

    g = _graph()
    idx = build_2dreach(g, variant="comp")
    eng = QueryEngine(idx)
    us, rects = _queries(g, 512)

    obs.reset()
    obs.enable()
    obs.FLIGHT.arm(dump_dir, min_interval_s=0.0)
    mon = obs.default_slos(obs.SLOMonitor(clock=time.time),
                           windows=(0.2, 0.8))
    ts = obs.start_timeseries(interval=0.05)
    ts.add_hook(lambda t, _s: mon.tick(t))

    ren = ResilientEngine(eng, idx, name="chaos",
                          retry=RetryPolicy(max_attempts=2, base_s=1e-4,
                                            cap_s=1e-3))
    fe = Frontend(ren, max_batch=64, max_delay=1e-3)
    try:
        fe.warmup(us[:64], rects[:64])
        # phase 1: clean traffic establishes the burn-rate baseline
        t_end = time.time() + 1.0
        while time.time() < t_end:
            _drive(fe, us[:64], rects[:64])
        assert not any(e["kind"] == "fired" for e in mon.events), \
            "SLO fired during the clean phase"

        # phase 2: every device batch raises -> retry -> exact host
        # degradation; the degraded fraction burns through its budget.
        # (The breaker opens within a few batches and freezes its own
        # bundle — keep driving until the *burn-rate* monitor fires,
        # which needs the long window to fill with degraded traffic.)
        plan = FaultPlan(
            FaultSpec("engine.query_batch", kind="raise", p=1.0,
                      max_fires=None),
            seed=7,
        )
        with inject(plan):
            t_end = time.time() + 5.0
            while time.time() < t_end and not any(
                    e["kind"] == "fired" for e in mon.events):
                _drive(fe, us[64:128], rects[64:128])
    finally:
        fe.close()
        obs.stop_timeseries()

    fired = [e for e in mon.events if e["kind"] == "fired"]
    assert fired, f"no SLO fired under chaos (events: {mon.events})"
    assert plan.total_fires > 0, "no faults actually fired"

    snap = obs.FLIGHT.snapshot()
    assert snap["dumps"] >= 1, f"burn fired but no bundle frozen: {snap}"
    # several triggers may have frozen bundles (the breaker opening is
    # itself one) — the gate targets the burn-triggered bundle
    manifests = {}
    for b in sorted(os.listdir(dump_dir)):
        with open(os.path.join(dump_dir, b, "manifest.json")) as f:
            manifests[b] = json.load(f)
    slo_bundles = [b for b, m in manifests.items()
                   if m["reason"].startswith("slo-")]
    assert slo_bundles, (
        f"burn fired but no slo-* bundle among "
        f"{[m['reason'] for m in manifests.values()]}")
    bundle = os.path.join(dump_dir, slo_bundles[0])
    manifest = manifests[slo_bundles[0]]
    print(f"[chaos-flight] SLO(s) fired: "
          f"{sorted({e['slo'] for e in fired})}; bundle {bundle}")

    # -- self-contained: every artifact present and parseable ----------
    for fname in BUNDLE_FILES:
        path = os.path.join(bundle, fname)
        assert os.path.exists(path), f"bundle missing {fname}"
    assert manifest["counts"]["spans"] > 0
    assert manifest["counts"]["querylog"] > 0

    # -- causally complete: p99 traces resolve end to end --------------
    rep = obs_flight.replay(bundle, top=8)
    assert rep["stories"], "no worst traces resolvable in the bundle"
    incomplete = [s["trace_id"] for s in rep["stories"]
                  if not s["complete"]]
    assert not incomplete, (
        f"p99 traces without a full causal chain: {incomplete}")
    # the p99 exemplars of the queue-wait histogram must be resolvable
    # requests too (the walkthrough the README documents)
    assert "frontend.queue_wait_us" in manifest["exemplars"], \
        "no queue-wait exemplars retained"
    assert rep["exemplar_ids"], "no exemplar trace ids to resolve"
    data = obs_flight.load_bundle(bundle)
    for tid in rep["exemplar_ids"]:
        story = obs_flight.resolve_trace(data, tid)
        assert story["complete"], (
            f"p99 exemplar trace {tid} does not resolve to a full "
            f"causal chain")
    # retries/degradation attribution made it into the frozen story
    assert any(e.get("kind") in ("engine.retry", "engine.degraded",
                                 "fault.injected")
               for e in data["events"]), "no chaos events in black box"
    assert any(r.get("status") == "degraded" for r in data["querylog"]), \
        "no degraded records in the frozen querylog window"

    # -- and the CLI agrees --------------------------------------------
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.flight", bundle, "--top", "8"],
        capture_output=True, text=True)
    print(proc.stdout)
    assert proc.returncode == 0, (
        f"replay CLI failed ({proc.returncode}):\n{proc.stderr}")

    n_ex = sum(len(v) for b in manifest["exemplars"].values()
               for v in b.values())
    print(f"[chaos-flight] PASS: bundle self-contained, "
          f"{len(rep['stories'])} p99 traces + "
          f"{len(rep['exemplar_ids'])} exemplar traces causally "
          f"complete, {n_ex} exemplars retained")
    obs.disable()
    obs.reset()
    return 0


if __name__ == "__main__":
    sys.exit(main())
