"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, 128 experts top-1 + shared, MoE interleaved every other
layer.  [hf:meta-llama/Llama-4 family; unverified]  Text backbone only
(early-fusion frontend is a stub per the assignment)."""
from ..models.lm import LMConfig, MoESpec
from .base import ArchSpec, lm_cells

NAME = "llama4-maverick-400b-a17b"


def make_config(reduced: bool = False, dtype: str = "bfloat16") -> LMConfig:
    if reduced:
        return LMConfig(
            name=NAME + "-reduced", n_layers=4, d_model=64, n_heads=8,
            n_kv_heads=2, head_dim=8, d_ff=128, vocab=512,
            layer_schedule="G", dtype="float32",
            moe=MoESpec(n_experts=8, top_k=1, d_expert=128, n_shared=1,
                        d_shared=128, interleave=2),
        )
    return LMConfig(
        name=NAME, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=8192, vocab=202048, layer_schedule="G",
        dtype=dtype,
        moe=MoESpec(n_experts=128, top_k=1, d_expert=8192, n_shared=1,
                    d_shared=8192, interleave=2),
    )


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="lm", make_config=make_config,
        cells=lm_cells(NAME, make_config),
        notes="full attention; long_500k runs with model-sharded KV "
              "(48L*500k*8*128*2*2B = 98 GB total, 192 MB/chip at 512)",
    )
