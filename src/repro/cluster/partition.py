"""Size-balanced partitioning of a 2DReach forest for sharded serving.

The 2DReach forest is embarrassingly partitionable: each component's 2D
R-tree is an independent lookup target, so any assignment of whole trees
to shards preserves exactness — a query probes exactly the shard that
owns its tree.  What matters is *balance*: per-shard work is
proportional to resident leaf entries (arena size bounds both memory and
the worst-case scan), so trees are bin-packed by entry count with the
classic LPT (longest-processing-time) greedy — sort descending, always
assign to the least-loaded shard — which is deterministic and within
4/3 of the optimal whole-tree assignment.  Whole trees are the unit of
placement, so when a single tree dominates the forest (a giant SCC) the
optimum itself is skewed and ``ForestPartition.balance()`` reports a
max/mean ratio well above 1.

The partition is summarised by three *replicated* per-tree arrays
(``tree_shard``, ``tree_qs``, ``tree_qe``): every device routes every
query's tree id to (owning shard, local arena slice) with plain gathers,
mirroring the single-device engine's fused lookup.  The per-shard
arenas themselves are stacked into one ``(S, 2*dim, Pp)`` plane (plus
the fine/coarse tile-pyramid planes) padded to a common width so the
stack shards cleanly over a mesh axis.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Tuple

import numpy as np

import jax.numpy as jnp

from ..core.rtree import RTreeForest, _ragged_arange
from ..kernels.range_query.descent import COARSE_GROUP, TPT, build_tile_pyramid
from ..kernels.range_query.kernel import TP
from ..kernels.range_query.ops import forest_soa


def balanced_assignment(weights: np.ndarray, n_shards: int) -> np.ndarray:
    """LPT greedy bin packing: (T,) weights -> (T,) shard ids.

    Deterministic: items are processed in descending weight order with
    index as tie-break, and ties between equally loaded shards go to the
    lowest shard id.
    """
    T = len(weights)
    assign = np.zeros(T, dtype=np.int32)
    if T == 0 or n_shards <= 1:
        return assign
    order = np.lexsort((np.arange(T), -np.asarray(weights, np.int64)))
    heap: List[Tuple[int, int]] = [(0, s) for s in range(n_shards)]
    heapq.heapify(heap)
    for t in order:
        load, s = heapq.heappop(heap)
        assign[t] = s
        heapq.heappush(heap, (load + int(weights[t]), s))
    return assign


@dataclasses.dataclass(frozen=True)
class ForestPartition:
    """Tree→shard assignment + replicated routing arrays.

    ``tree_shard``/``tree_qs``/``tree_qe`` are padded to length
    ``max(T, 1)`` so an empty forest still gathers safely (every lookup
    then resolves to shard -1 / an empty slice).
    """

    n_shards: int
    shard_trees: Tuple[np.ndarray, ...]  # ascending global tree ids
    tree_shard: np.ndarray               # (max(T,1),) int32, -1 pad
    tree_qs: np.ndarray                  # (max(T,1),) int32 local start
    tree_qe: np.ndarray                  # (max(T,1),) int32 local end
    shard_entries: np.ndarray            # (S,) int64 resident leaf entries

    @property
    def n_trees(self) -> int:
        return sum(len(t) for t in self.shard_trees)

    def balance(self) -> float:
        """max/mean shard load (1.0 = perfectly balanced)."""
        mean = self.shard_entries.mean() if self.n_shards else 0.0
        return float(self.shard_entries.max() / mean) if mean > 0 else 1.0


def partition_forest(forest: RTreeForest, n_shards: int) -> ForestPartition:
    """Assign whole trees to ``n_shards`` size-balanced shards."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    T = forest.n_trees
    counts = np.diff(forest.entry_off).astype(np.int64)
    assign = balanced_assignment(counts, n_shards)
    shard_trees = tuple(
        np.nonzero(assign == s)[0].astype(np.int64) for s in range(n_shards)
    )
    pad = max(T, 1)
    tree_shard = np.full(pad, -1, dtype=np.int32)
    tree_qs = np.zeros(pad, dtype=np.int32)
    tree_qe = np.zeros(pad, dtype=np.int32)
    shard_entries = np.zeros(n_shards, dtype=np.int64)
    for s, trees in enumerate(shard_trees):
        lo = 0
        for t in trees:
            c = int(counts[t])
            tree_shard[t] = s
            tree_qs[t] = lo
            tree_qe[t] = lo + c
            lo += c
        shard_entries[s] = lo
    return ForestPartition(
        n_shards=n_shards,
        shard_trees=shard_trees,
        tree_shard=tree_shard,
        tree_qs=tree_qs,
        tree_qe=tree_qe,
        shard_entries=shard_entries,
    )


def shard_arenas(
    forest: RTreeForest, part: ForestPartition
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Stacked per-shard SoA arenas + tile pyramids.

    Returns ``(entries (S, 2*dim, Pp), fine (S, 2*dim, NTp),
    coarse (S, 2*dim, NTp // COARSE_GROUP), n_tiles)`` — every shard
    padded to the *common* width ``Pp`` (the max shard's TP-rounded
    entry count) with impossible boxes (min > max), so padding tiles
    have impossible MBRs and never activate.  ``n_tiles = Pp // TP`` is
    therefore uniform across shards, which keeps the shard_map program
    one trace.

    A forest built with ``build_forest_device`` carries its serving
    arrays on device already; the shard stacks are then *gathered on
    device* from the resident global plane (and the per-shard pyramids
    reduced there too) — no host transposition, no host→device
    re-upload.  Both paths produce identical float32 planes.
    """
    dev = getattr(forest, "device", None)
    if dev is not None:
        return _shard_arenas_device(forest, part, dev)
    from ..core.engine import UPLOAD_COUNTERS  # deferred: engine is heavy

    UPLOAD_COUNTERS["host_uploads"] += 1
    esoa, off = forest_soa(forest)           # cached global transposition
    dim = forest.dim
    S = part.n_shards
    Pp = max(TP, -(-int(part.shard_entries.max(initial=0)) // TP) * TP)
    entries = np.empty((S, 2 * dim, Pp), dtype=np.float32)
    entries[:, :dim] = 1.0                    # impossible box padding
    entries[:, dim:] = 0.0
    for s, trees in enumerate(part.shard_trees):
        lo = 0
        for t in trees:
            a, b = int(off[t]), int(off[t + 1])
            entries[s, :, lo:lo + (b - a)] = esoa[:, a:b]
            lo += b - a
    fine_l, coarse_l = [], []
    nt = Pp // TP
    for s in range(S):
        fine, coarse, nt_s = build_tile_pyramid(entries[s], dim)
        assert nt_s == nt
        fine_l.append(fine)
        coarse_l.append(coarse)
    return entries, np.stack(fine_l), np.stack(coarse_l), nt


def _shard_arenas_device(
    forest: RTreeForest, part: ForestPartition, dev
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """``shard_arenas`` for a device-built forest: gather each shard's
    arena from the resident global entry plane and reduce the per-shard
    tile pyramids on device.  Identical planes to the host path."""
    import jax

    from ..core.engine import UPLOAD_COUNTERS  # deferred: engine is heavy
    from ..kernels.forest_build import (
        default_build_kernel,
        np_inert_plane,
        tile_pyramid_device,
    )

    UPLOAD_COUNTERS["device_adoptions"] += 1
    dim = forest.dim
    S = part.n_shards
    off = forest.entry_off
    Pp = max(TP, -(-int(part.shard_entries.max(initial=0)) // TP) * TP)
    Pg = int(dev.entries.shape[1])
    # host-computed gather map (small ints); sentinel Pg -> inert column
    pos = np.full((S, Pp), Pg, dtype=np.int32)
    for s, trees in enumerate(part.shard_trees):
        if len(trees):
            cnt = (off[trees + 1] - off[trees]).astype(np.int64)
            within = _ragged_arange(cnt)
            dstp = np.repeat(
                np.r_[0, np.cumsum(cnt)[:-1]], cnt) + within
            srcp = np.repeat(off[trees], cnt) + within
            pos[s, dstp] = srcp
    src = jnp.concatenate(
        [dev.entries, jnp.asarray(np_inert_plane(dim, 1))], axis=1)
    entries = jnp.take(
        src, jnp.asarray(pos.reshape(-1)), axis=1
    ).reshape(2 * dim, S, Pp).transpose(1, 0, 2)

    kernel = default_build_kernel()
    interpret = jax.default_backend() != "tpu"
    fine_l, coarse_l = [], []
    nt = Pp // TP
    for s in range(S):
        fine, coarse, nt_s = tile_pyramid_device(
            entries[s], dim, tp=TP, tpt=TPT, group=COARSE_GROUP,
            kernel=kernel, interpret=interpret,
        )
        assert nt_s == nt
        fine_l.append(fine)
        coarse_l.append(coarse)
    return entries, jnp.stack(fine_l), jnp.stack(coarse_l), nt
