"""SCC condensation: collapse components into a DAG + topological layering.

The condensation D = (V_D, E_D) has one super-vertex per SCC and an edge
(C_u -> C_v) iff G has an edge between members of distinct components.

Downstream (reachability closure, Alg. 1 of the paper) only needs:

  comp          (n,)  int32   dense component id per vertex
  n_comps       int
  dag_edges     (e, 2) int32  deduplicated inter-component edges
  level         (d,)  int32   longest-path depth from sources; for every
                              DAG edge (u, v): level[u] < level[v].
                              Processing levels in descending order is the
                              reverse-topological traversal of Alg. 1.

Levels (rather than a single topological permutation) are the data-parallel
form of "reverse topological order": all components on one level can be
processed in a single vectorised sweep.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .scc import compact_labels


@dataclasses.dataclass
class Condensation:
    comp: np.ndarray        # (n,)   vertex -> dense comp id
    n_comps: int
    dag_edges: np.ndarray   # (e, 2) comp -> comp, deduped, no self loops
    level: np.ndarray       # (d,)   longest-path level from sources
    comp_sizes: np.ndarray  # (d,)   member counts

    @property
    def n_levels(self) -> int:
        return int(self.level.max()) + 1 if self.n_comps else 0

    def edges_by_level_desc(self) -> np.ndarray:
        """DAG edges sorted by level[src] descending — the order in which
        the reverse-topological closure consumes them."""
        if self.dag_edges.size == 0:
            return self.dag_edges
        order = np.argsort(-self.level[self.dag_edges[:, 0]], kind="stable")
        return self.dag_edges[order]


def condense(
    n: int,
    edges: np.ndarray,
    labels: np.ndarray,
    include_mask: Optional[np.ndarray] = None,
) -> Condensation:
    """Build the condensation from per-vertex SCC labels (any labelling).

    ``include_mask`` (n,) bool excludes vertices from the decomposition —
    the compressed 2DReach variants build the condensation on the social
    subgraph only; excluded vertices get ``comp == -1`` and the supplied
    ``edges`` must already be restricted to included endpoints.
    """
    if include_mask is not None:
        include_mask = np.asarray(include_mask, dtype=bool)
        inc_ids = np.nonzero(include_mask)[0]
        sub, d = compact_labels(np.asarray(labels)[inc_ids])
        comp = np.full(n, -1, dtype=np.int32)
        comp[inc_ids] = sub
    else:
        comp, d = compact_labels(labels)
    edges = np.asarray(edges).reshape(-1, 2)
    if edges.size:
        ce = comp[edges]                      # (m, 2) comp ids
        ce = ce[ce[:, 0] != ce[:, 1]]         # drop intra-component edges
        if ce.size:
            key = ce[:, 0].astype(np.int64) << 32 | ce[:, 1].astype(np.int64)
            uniq = np.unique(key)
            dag_edges = np.stack(
                [uniq >> 32, uniq & 0xFFFFFFFF], axis=1
            ).astype(np.int32)
        else:
            dag_edges = np.zeros((0, 2), dtype=np.int32)
    else:
        dag_edges = np.zeros((0, 2), dtype=np.int32)

    level = _longest_path_levels(d, dag_edges)
    comp_sizes = np.bincount(comp[comp >= 0], minlength=d).astype(np.int64)
    return Condensation(
        comp=comp, n_comps=d, dag_edges=dag_edges, level=level,
        comp_sizes=comp_sizes,
    )


def _longest_path_levels(d: int, dag_edges: np.ndarray) -> np.ndarray:
    """Longest-path-from-source levels via Kahn-style sweeps.

    O(E) per level using a frontier queue; NumPy implementation (the build
    is host-side; the jit path recomputes levels only if the DAG changed,
    which it never does after build).
    """
    level = np.zeros(d, dtype=np.int32)
    if dag_edges.size == 0 or d == 0:
        return level
    indeg = np.bincount(dag_edges[:, 1], minlength=d).astype(np.int64)
    # CSR over DAG out-edges
    order = np.argsort(dag_edges[:, 0], kind="stable")
    src_sorted = dag_edges[order, 0]
    dst_sorted = dag_edges[order, 1]
    indptr = np.zeros(d + 1, dtype=np.int64)
    np.cumsum(np.bincount(src_sorted, minlength=d), out=indptr[1:])

    frontier = np.nonzero(indeg == 0)[0]
    seen = 0
    while frontier.size:
        seen += frontier.size
        # gather all out-edges of the frontier
        starts = indptr[frontier]
        ends = indptr[frontier + 1]
        counts = (ends - starts).astype(np.int64)
        if counts.sum() == 0:
            break
        # ragged gather of edge slots
        slot = np.repeat(starts, counts) + _ragged_arange(counts)
        dsts = dst_sorted[slot]
        srcs = src_sorted[slot]
        np.maximum.at(level, dsts, level[srcs] + 1)
        np.subtract.at(indeg, dsts, 1)
        cand = np.unique(dsts)
        frontier = cand[indeg[cand] == 0]
    if seen != d:
        # cycle in "DAG" — impossible after SCC condensation
        raise AssertionError("condensation contained a cycle")
    return level


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated."""
    total = int(counts.sum())
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
