"""Re-export: the scan-aware HLO analyzer lives in repro.analysis."""
from repro.analysis.hlo_stats import *          # noqa: F401,F403
from repro.analysis.hlo_stats import _parse_computations  # noqa: F401
