"""Segmented-MBR reduction kernel for on-device R-tree bulk-load."""

from .kernel import TN, seg_mbr_pallas
from .ops import (
    default_build_kernel,
    gather_child_slots,
    level_mbr,
    mbr_reduce,
    np_inert_plane,
    slot_major,
    tile_pyramid_device,
)
from .ref import seg_mbr_ref

__all__ = [
    "TN", "seg_mbr_pallas", "seg_mbr_ref",
    "default_build_kernel", "gather_child_slots", "level_mbr",
    "mbr_reduce", "np_inert_plane", "slot_major", "tile_pyramid_device",
]
