"""Unit tests for the resilience primitives.

Fault plans, deadline budgets, retry jitter, the circuit breaker state
machine, and the resilient engine wrapper — all driven with fake clocks
and injected rngs so every schedule is deterministic.  The end-to-end
chaos invariant lives in ``test_chaos.py``.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import build_index, rangereach_oracle_batch
from repro.obs.metrics import Registry
from repro.resilience import (
    BreakerPolicy,
    CircuitBreaker,
    Deadline,
    DeadlineExceeded,
    FaultPlan,
    FaultSpec,
    INJECTOR,
    InjectedFault,
    ResilientEngine,
    RetryPolicy,
    ShardDropout,
    fault_point,
    inject,
)
from repro.resilience.breaker import CLOSED, HALF_OPEN, OPEN
from conftest import random_geosocial, random_queries


class Ticker:
    """Manually advanced monotonic clock."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ----------------------------------------------------------------------
# fault plans / injection
# ----------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("p", kind="explode")
    with pytest.raises(ValueError):
        FaultSpec("p", p=1.5)


def test_fault_point_disabled_is_noop():
    assert not INJECTOR.enabled
    fault_point("engine.query_batch", n=4)   # must not raise


def test_plan_fires_deterministically():
    def run(seed):
        plan = FaultPlan(
            FaultSpec("pt", kind="raise", p=0.5, max_fires=None),
            seed=seed)
        fired = []
        with inject(plan):
            for i in range(50):
                try:
                    fault_point("pt")
                    fired.append(0)
                except InjectedFault:
                    fired.append(1)
        return fired, plan.total_fires

    a, na = run(7)
    b, nb = run(7)
    c, nc = run(8)
    assert a == b and na == nb
    assert a != c                       # different seed, different draw
    assert 0 < na < 50                  # p=0.5 actually probabilistic


def test_plan_after_and_max_fires():
    plan = FaultPlan(FaultSpec("pt", after=2, max_fires=3))
    hits, fires = 10, 0
    with inject(plan):
        for i in range(hits):
            try:
                fault_point("pt")
            except InjectedFault as e:
                fires += 1
                assert i >= 2           # first two hits skipped
                assert e.point == "pt"
    assert fires == 3
    assert plan.hits_at("pt") == hits
    assert plan.fires_at("pt") == 3


def test_injected_counters_land_in_registry():
    from repro.obs.metrics import REGISTRY

    before = REGISTRY.counter("faults.injected").value
    with inject(FaultPlan(FaultSpec("pt.counted", max_fires=2))):
        for _ in range(4):
            try:
                fault_point("pt.counted")
            except InjectedFault:
                pass
    assert REGISTRY.counter("faults.injected").value == before + 2
    assert REGISTRY.counter("faults.pt.counted").value >= 2


def test_uninstall_releases_pending_hang():
    import threading

    plan = FaultPlan(FaultSpec("pt.hang", kind="hang", hang_s=60.0))
    stalled = threading.Event()
    done = threading.Event()

    def worker():
        stalled.set()
        fault_point("pt.hang")          # blocks until release
        done.set()

    INJECTOR.install(plan)
    try:
        t = threading.Thread(target=worker, daemon=True)
        t.start()
        assert stalled.wait(timeout=10)
        assert not done.wait(timeout=0.05)   # genuinely stalled
    finally:
        INJECTOR.uninstall()            # sets plan.release
    assert done.wait(timeout=10), "uninstall must end the hang"


# ----------------------------------------------------------------------
# deadlines / retry
# ----------------------------------------------------------------------


def test_deadline_budget():
    clk = Ticker()
    dl = Deadline(1.0, clock=clk)
    assert not dl.expired() and dl.remaining() == pytest.approx(1.0)
    clk.t = 0.75
    assert dl.remaining() == pytest.approx(0.25)
    dl.check()                          # still inside budget
    clk.t = 1.0
    assert dl.expired()
    with pytest.raises(DeadlineExceeded):
        dl.check("probe")
    assert Deadline.none().remaining() == np.inf
    assert not Deadline(None).expired()


def test_retry_backoff_bounded_and_deterministic():
    pol = RetryPolicy(max_attempts=6, base_s=1e-3, cap_s=20e-3)
    sched = pol.schedule(np.random.default_rng(3))
    assert sched == pol.schedule(np.random.default_rng(3))
    assert len(sched) == 5
    prev = 0.0
    for s in sched:
        assert pol.base_s <= s <= pol.cap_s
        assert s <= max(pol.base_s, 3.0 * prev) + 1e-12  # decorrelated
        prev = s


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(base_s=2.0, cap_s=1.0)


# ----------------------------------------------------------------------
# circuit breaker
# ----------------------------------------------------------------------


def _breaker(clk, **kw):
    return CircuitBreaker("t", BreakerPolicy(**kw), clock=clk,
                          registry=Registry())


def test_breaker_opens_after_threshold():
    clk = Ticker()
    br = _breaker(clk, failure_threshold=3, reset_timeout_s=5.0)
    for _ in range(2):
        assert br.allow()
        br.record_failure()
    assert br.state == CLOSED           # 2 < threshold
    assert br.allow()
    br.record_failure()
    assert br.state == OPEN
    assert not br.allow()               # open refuses outright


def test_breaker_success_resets_failure_streak():
    clk = Ticker()
    br = _breaker(clk, failure_threshold=2)
    br.record_failure()
    br.record_success()                 # streak broken
    br.record_failure()
    assert br.state == CLOSED


def test_breaker_half_open_probe_protocol():
    clk = Ticker()
    br = _breaker(clk, failure_threshold=1, reset_timeout_s=10.0)
    br.record_failure()
    assert br.state == OPEN
    clk.t = 9.9
    assert not br.allow()
    clk.t = 10.0
    assert br.state == HALF_OPEN
    assert br.allow()                   # the single probe slot
    assert not br.allow()               # concurrent caller refused
    br.record_success()
    assert br.state == CLOSED


def test_breaker_probe_failure_reopens():
    clk = Ticker()
    br = _breaker(clk, failure_threshold=1, reset_timeout_s=1.0)
    br.record_failure()
    clk.t = 1.0
    assert br.allow()
    br.record_failure()                 # failed probe
    assert br.state == OPEN
    assert not br.allow()               # timeout restarted
    clk.t = 2.0
    assert br.allow()


def test_breaker_release_frees_probe_slot():
    clk = Ticker()
    br = _breaker(clk, failure_threshold=1, reset_timeout_s=1.0)
    br.record_failure()
    clk.t = 1.0
    assert br.allow()
    br.release()                        # grant unused: no outcome
    assert br.state == HALF_OPEN
    assert br.allow()                   # slot available again


def test_breaker_trip_and_policy_validation():
    clk = Ticker()
    br = _breaker(clk, reset_timeout_s=100.0)
    br.trip()
    assert br.state == OPEN and not br.allow()
    with pytest.raises(ValueError):
        BreakerPolicy(failure_threshold=0)
    with pytest.raises(ValueError):
        BreakerPolicy(reset_timeout_s=-1.0)


# ----------------------------------------------------------------------
# resilient engine
# ----------------------------------------------------------------------


class FlakyDevice:
    """Delegates to the host index; raises on scheduled call numbers."""

    def __init__(self, index, fail_calls=(), exc=None):
        self.index = index
        self.fail_calls = set(fail_calls)
        self.exc = exc or InjectedFault("flaky")
        self.calls = 0

    def query_batch(self, us, rects):
        self.calls += 1
        if self.calls in self.fail_calls:
            raise self.exc
        return self.index.query_batch(us, rects)


@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(11)
    g = random_geosocial(rng, 120, 320)
    idx = build_index(g, "2dreach")
    us, rects = random_queries(rng, g, 64)
    want = rangereach_oracle_batch(g, us, rects)
    return idx, us, rects, want


def _resilient(idx, dev, clk=None, **kw):
    clk = clk or Ticker()
    kw.setdefault("retry", RetryPolicy(max_attempts=3, base_s=1e-4,
                                       cap_s=1e-3))
    kw.setdefault("breaker", BreakerPolicy(failure_threshold=2,
                                           reset_timeout_s=1.0))
    return ResilientEngine(dev, idx, clock=clk, sleep=lambda s: None,
                           registry=Registry(), **kw)


def test_resilient_healthy_passthrough(small_index):
    idx, us, rects, want = small_index
    dev = FlakyDevice(idx)
    res = _resilient(idx, dev)
    got = res.query_batch(us, rects)
    np.testing.assert_array_equal(got, want)
    assert res.stats["device_batches"] == 1
    assert res.stats["fallback_batches"] == 0
    assert not res.degraded


def test_resilient_retry_recovers_exactly(small_index):
    idx, us, rects, want = small_index
    dev = FlakyDevice(idx, fail_calls={1})      # first attempt fails
    res = _resilient(idx, dev)
    np.testing.assert_array_equal(res.query_batch(us, rects), want)
    assert res.stats["retries"] == 1
    assert res.stats["device_failures"] == 1
    assert res.stats["fallback_batches"] == 0


def test_resilient_exhaustion_degrades_exactly(small_index):
    idx, us, rects, want = small_index
    dev = FlakyDevice(idx, fail_calls=set(range(1, 100)))
    res = _resilient(idx, dev)
    np.testing.assert_array_equal(res.query_batch(us, rects), want)
    assert res.stats["fallback_batches"] == 1
    assert res.stats["fallback_queries"] == len(us)
    # two consecutive failures opened the breaker
    assert res.breaker.state == OPEN and res.degraded
    # while open, queries go straight to host — no device calls at all
    calls = dev.calls
    np.testing.assert_array_equal(res.query_batch(us, rects), want)
    assert dev.calls == calls


def test_resilient_deadline_exhaustion_falls_back(small_index):
    idx, us, rects, want = small_index
    clk = Ticker()
    dev = FlakyDevice(idx, fail_calls={1, 2, 3})

    def sleep(s):
        clk.t += s

    res = ResilientEngine(
        dev, idx, retry=RetryPolicy(max_attempts=5, base_s=0.4,
                                    cap_s=0.4),
        breaker=BreakerPolicy(failure_threshold=10),
        clock=clk, sleep=sleep, registry=Registry())
    got = res.query_batch(us, rects, deadline=0.5)
    np.testing.assert_array_equal(got, want)
    # one failure + one 0.4s backoff + one more failure exhausts 0.5s
    assert res.stats["fallback_batches"] == 1
    assert clk.t <= 0.5 + 1e-9          # never slept past the budget


def test_resilient_trip_forces_degraded(small_index):
    idx, us, rects, want = small_index
    dev = FlakyDevice(idx)
    res = _resilient(idx, dev)
    res.trip()
    np.testing.assert_array_equal(res.query_batch(us, rects), want)
    assert dev.calls == 0 and res.degraded


class ShardedFlaky:
    """Two-shard device sim: shard = u % 2; shard 1 always drops."""

    def __init__(self, index, dead_shard=1):
        self.index = index
        self.dead = dead_shard
        self.calls = []

    def shard_of(self, us):
        return np.asarray(us) % 2

    def query_batch(self, us, rects):
        us = np.asarray(us)
        self.calls.append(us.copy())
        if (self.shard_of(us) == self.dead).any():
            raise ShardDropout(self.dead, "cluster.query_batch")
        return self.index.query_batch(us, rects)


def test_resilient_shard_dropout_degrades_only_that_shard(small_index):
    idx, us, rects, want = small_index
    dev = ShardedFlaky(idx)
    res = _resilient(idx, dev,
                     breaker=BreakerPolicy(failure_threshold=1,
                                           reset_timeout_s=100.0))
    np.testing.assert_array_equal(res.query_batch(us, rects), want)
    assert res.shard_breaker(1).state == OPEN
    assert res.breaker.state == CLOSED  # engine itself stays healthy
    # second batch: dead shard filtered before the device call, healthy
    # shard served on device, remainder host-filled — still exact
    np.testing.assert_array_equal(res.query_batch(us, rects), want)
    assert (res.shard_breaker(1).state == OPEN)
    last = dev.calls[-1]
    assert (last % 2 == 0).all()        # no dead-shard query on device
    assert res.stats["fallback_queries"] >= int((us % 2 == 1).sum())


def test_resilient_analytics_fallback_exact(small_index):
    idx, us, rects, want = small_index
    from repro.queries.host import range_count_host

    dev = FlakyDevice(idx)              # exposes no count_batch at all
    res = _resilient(idx, dev)
    got = res.count_batch(us, rects)
    np.testing.assert_array_equal(got, range_count_host(idx, us, rects))
    assert res.stats["fallback_batches"] == 1
    assert res.stats["fallback_queries"] == len(us)
