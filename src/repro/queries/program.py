"""Typed query programs + result containers for the analytics classes.

A :class:`QueryProgram` is a validated, batched description of one
query-class invocation — the unit ``core.api.run_queries`` executes on
any engine.  Five kinds:

=========  ===========================================  ================
kind       parameters                                   result
=========  ===========================================  ================
reach      us (B,), rects (B, 4)                        (B,) bool
count      us (B,), rects (B, 4)                        (B,) int64
collect    us (B,), rects (B, 4), k                     CollectResult
knn        us (B,), points (B, 2), k                    KNNResult
polygon    us (B,), polygons (B sequences of (Ei, 2))   (B,) bool
=========  ===========================================  ================

Construct via the classmethods (``QueryProgram.count(us, rects)``, ...)
so the shapes are checked once up front instead of deep inside an
engine.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

QUERY_KINDS = ("reach", "count", "collect", "knn", "polygon")


@dataclasses.dataclass
class CollectResult:
    """RangeCollect answers: per query the K smallest reachable venue
    ids in the region (ascending, -1 padded), the exact total count,
    and whether the region held more than K."""

    ids: np.ndarray       # (B, K) int32, -1 padded
    counts: np.ndarray    # (B,) int64 exact totals
    overflow: np.ndarray  # (B,) bool — counts > K

    def row(self, b: int) -> np.ndarray:
        r = self.ids[b]
        return r[r >= 0]


@dataclasses.dataclass
class KNNResult:
    """KNNReach answers: per query the k nearest reachable venues by
    (dist², id) ascending (-1 / +inf padded when fewer exist)."""

    ids: np.ndarray    # (B, k) int32, -1 padded
    dist2: np.ndarray  # (B, k) float64 squared distances, +inf padded

    def row(self, b: int) -> np.ndarray:
        r = self.ids[b]
        return r[r >= 0]


@dataclasses.dataclass(frozen=True)
class QueryProgram:
    """One batched query-class invocation (see module docstring)."""

    kind: str
    us: np.ndarray
    rects: Optional[np.ndarray] = None
    points: Optional[np.ndarray] = None
    polygons: Optional[Tuple[np.ndarray, ...]] = None
    k: Optional[int] = None

    @property
    def n_queries(self) -> int:
        return len(self.us)

    # -- constructors ---------------------------------------------------

    @staticmethod
    def _us(us) -> np.ndarray:
        us = np.asarray(us, dtype=np.int64).reshape(-1)
        return us

    @staticmethod
    def _rects(rects, B: int) -> np.ndarray:
        rects = np.asarray(rects, dtype=np.float32).reshape(B, 4)
        return rects

    @classmethod
    def reach(cls, us, rects) -> "QueryProgram":
        us = cls._us(us)
        return cls(kind="reach", us=us, rects=cls._rects(rects, len(us)))

    @classmethod
    def count(cls, us, rects) -> "QueryProgram":
        us = cls._us(us)
        return cls(kind="count", us=us, rects=cls._rects(rects, len(us)))

    @classmethod
    def collect(cls, us, rects, k: int) -> "QueryProgram":
        us = cls._us(us)
        k = int(k)
        if k < 1:
            raise ValueError(f"collect needs k >= 1, got {k}")
        return cls(kind="collect", us=us, rects=cls._rects(rects, len(us)),
                   k=k)

    @classmethod
    def knn(cls, us, points, k: int) -> "QueryProgram":
        us = cls._us(us)
        k = int(k)
        if k < 1:
            raise ValueError(f"knn needs k >= 1, got {k}")
        points = np.asarray(points, dtype=np.float32).reshape(len(us), 2)
        return cls(kind="knn", us=us, points=points, k=k)

    @classmethod
    def polygon(cls, us, polygons: Sequence) -> "QueryProgram":
        us = cls._us(us)
        if len(polygons) != len(us):
            raise ValueError(
                f"{len(polygons)} polygons for {len(us)} queries")
        polys = tuple(
            np.asarray(p, dtype=np.float32).reshape(-1, 2) for p in polygons
        )
        for p in polys:
            if len(p) < 3:
                raise ValueError("polygons need >= 3 vertices")
        return cls(kind="polygon", us=us, polygons=polys)
