"""Agrawal-Borgida-Jagadish interval labelling over the SCC condensation.

This is the 3DReach baseline's reachability encoding (the part the paper
eliminates).  Each component c gets

* a DFS spanning-forest **post-order number** ``post[c]``, and
* a merged list of **intervals** such that c' is reachable from c iff
  ``post[c']`` lies inside one of c's intervals.

Built host-side with an iterative DFS (the condensation is a DAG so every
edge (u, v) satisfies ``post[v] < post[u]``; processing components by
ascending post order is therefore a reverse-topological traversal and each
component's label is own-tree-interval ∪ children's labels, merged).

The paper's observation that this labelling "is costly, and can amount to
millions of intervals in large graphs" is reproduced by ``total_intervals``
(benchmarks report it as 3DReach's labelling storage).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .condensation import Condensation


@dataclasses.dataclass
class IntervalLabels:
    post: np.ndarray        # (d,) int32 post-order number per comp
    indptr: np.ndarray      # (d+1,) int64 — intervals of comp c at
    lo: np.ndarray          # (I,) int32      indptr[c]:indptr[c+1]
    hi: np.ndarray          # (I,) int32

    @property
    def total_intervals(self) -> int:
        return int(len(self.lo))

    def nbytes(self) -> int:
        return int(
            self.post.nbytes + self.indptr.nbytes + self.lo.nbytes
            + self.hi.nbytes
        )

    def covers(self, c: int, z: int) -> bool:
        s, e = self.indptr[c], self.indptr[c + 1]
        if s == e:
            return False
        j = np.searchsorted(self.lo[s:e], z, side="right") - 1
        return j >= 0 and z <= self.hi[s + j]


def _dag_csr(d: int, dag_edges: np.ndarray):
    if dag_edges.size == 0:
        return (np.zeros(d + 1, dtype=np.int64), np.zeros(0, dtype=np.int32))
    order = np.argsort(dag_edges[:, 0], kind="stable")
    src = dag_edges[order, 0]
    dst = dag_edges[order, 1].astype(np.int32)
    indptr = np.zeros(d + 1, dtype=np.int64)
    np.cumsum(np.bincount(src, minlength=d), out=indptr[1:])
    return indptr, dst


def build_interval_labels(cond: Condensation) -> IntervalLabels:
    d = cond.n_comps
    indptr, adj = _dag_csr(d, cond.dag_edges)

    # ---- iterative DFS post-order over the spanning forest --------------
    post = np.full(d, -1, dtype=np.int64)
    low = np.full(d, -1, dtype=np.int64)   # min post in own DFS subtree
    indeg = np.zeros(d, dtype=np.int64)
    if cond.dag_edges.size:
        np.add.at(indeg, cond.dag_edges[:, 1], 1)
    roots = np.nonzero(indeg == 0)[0]

    counter = 0
    visited = np.zeros(d, dtype=bool)
    # stack of (node, next-child-cursor)
    for r in roots:
        if visited[r]:
            continue
        stack: List[List[int]] = [[int(r), int(indptr[r])]]
        visited[r] = True
        while stack:
            node, cur = stack[-1]
            end = indptr[node + 1]
            advanced = False
            while cur < end:
                ch = adj[cur]
                cur += 1
                if not visited[ch]:
                    visited[ch] = True
                    stack[-1][1] = cur
                    stack.append([int(ch), int(indptr[ch])])
                    advanced = True
                    break
            if not advanced:
                stack[-1][1] = cur
            if not advanced:
                post[node] = counter
                counter += 1
                stack.pop()
    assert counter == d, "DFS must visit every component of the DAG"

    # ---- merge labels in ascending post order (children first) ----------
    order = np.argsort(post, kind="stable")
    labels: List[List[Tuple[int, int]]] = [[] for _ in range(d)]
    for c in order:
        ivs: List[Tuple[int, int]] = []
        sub_low = post[c]
        s, e = indptr[c], indptr[c + 1]
        for ch in adj[s:e]:
            ivs.extend(labels[ch])
            # note: tree-vs-non-tree does not matter once children's labels
            # are complete; own subtree interval is implied by merging
            # [post[c], post[c]] with the children's intervals when the DFS
            # numbering is contiguous, but cross edges break contiguity, so
            # we merge explicitly.
        ivs.append((int(post[c]), int(post[c])))
        ivs.sort()
        merged: List[Tuple[int, int]] = []
        for a, b in ivs:
            if merged and a <= merged[-1][1] + 1:
                if b > merged[-1][1]:
                    merged[-1] = (merged[-1][0], b)
            else:
                merged.append((a, b))
        labels[c] = merged
        del sub_low

    counts = np.array([len(l) for l in labels], dtype=np.int64)
    out_indptr = np.zeros(d + 1, dtype=np.int64)
    np.cumsum(counts, out=out_indptr[1:])
    total = int(out_indptr[-1])
    lo = np.empty(total, dtype=np.int32)
    hi = np.empty(total, dtype=np.int32)
    for c in range(d):
        s = out_indptr[c]
        for k, (a, b) in enumerate(labels[c]):
            lo[s + k] = a
            hi[s + k] = b
    return IntervalLabels(
        post=post.astype(np.int32), indptr=out_indptr, lo=lo, hi=hi
    )


def labels_reachable(lbl: IntervalLabels, u_comp: int, v_comp: int) -> bool:
    """Oracle helper: is v_comp reachable from u_comp per the labels."""
    return lbl.covers(u_comp, int(lbl.post[v_comp]))
