"""Causal trace propagation: mint -> scope -> spans/querylog/exemplars.

The flight-recorder promise rests on one invariant: a trace id minted
at ``Frontend.submit`` is resolvable in every artifact the request
touched — padder/megakernel spans, the shard fan-out, retry and
degradation attribution, querylog v3 rows, histogram exemplars.  These
tests pin that invariant layer by layer, plus the per-thread interval
accounting (coverage can never exceed 100% under concurrent flush
threads) and the time-series final-sample flush.
"""

from __future__ import annotations

import threading

import numpy as np
import pytest

from conftest import random_geosocial, random_queries
from repro import obs
from repro.obs import trace_context
from repro.obs.metrics import Histogram
from repro.obs.querylog import I_ATTEMPT, I_TRACE_ID, QueryLog
from repro.obs.timeseries import TimeSeriesCollector
from repro.obs.tracer import Tracer
from repro.resilience.engine import ResilientEngine
from repro.resilience.faults import FaultPlan, FaultSpec, inject
from repro.resilience.retry import RetryPolicy


@pytest.fixture(autouse=True)
def _obs_clean():
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(7)
    g = random_geosocial(rng, 400, 1200)
    from repro.core import QueryEngine, build_2dreach

    idx = build_2dreach(g, variant="comp")
    eng = QueryEngine(idx)
    us, rects = random_queries(rng, g, 128)
    return g, idx, eng, us, rects


# ------------------------------------------------------------ context


def test_mint_ids_unique_and_monotone():
    ids = [trace_context.mint().trace_id for _ in range(100)]
    assert len(set(ids)) == 100
    assert ids == sorted(ids)


def test_scope_nesting_and_thread_isolation():
    a, b = trace_context.mint(u=1), trace_context.mint(u=2)
    assert trace_context.current() is None
    with trace_context.scope([a]):
        assert trace_context.current_ids() == [a.trace_id]
        with trace_context.scope([b]):          # innermost wins
            assert trace_context.current_ids() == [b.trace_id]
        assert trace_context.current_ids() == [a.trace_id]
        seen = []
        t = threading.Thread(
            target=lambda: seen.append(trace_context.current()))
        t.start()
        t.join()
        assert seen == [None]       # scopes never leak across threads
    assert trace_context.current() is None


def test_disabled_spans_record_nothing_under_scope():
    ctx = trace_context.mint()
    with trace_context.scope([ctx]):
        with obs.span("engine.query_batch", cat="engine"):
            pass
    assert len(obs.TRACER) == 0


# ----------------------------------------------- engine span propagation


def test_padder_bucketing_spans_carry_trace_ids(built):
    """A non-power-of-two batch pads to its bucket; the pad/fused spans
    must still carry exactly the *real* requests' ids."""
    _, _, eng, us, rects = built
    B = 5                                   # pads to the 8-bucket
    ctxs = [trace_context.mint(u=int(u)) for u in us[:B]]
    eng.query_batch(us[:B], rects[:B])      # warm outside the scope
    obs.enable()
    with trace_context.scope(ctxs):
        eng.query_batch(us[:B], rects[:B])
    want = [c.trace_id for c in ctxs]
    by_name = {e[0]: e[5] for e in obs.TRACER.events()}
    for name in ("engine.query_batch", "engine.pad_batch"):
        assert name in by_name, sorted(by_name)
        assert by_name[name]["trace_ids"] == want, name


def test_shard_fanout_spans_and_futures_carry_ids(built):
    """8-shard ShardedEngine behind the Frontend: futures expose their
    trace id, cluster spans carry the batch's ids, and the querylog v3
    rows join on them."""
    from repro.cluster import Frontend, ShardedEngine

    _, idx, _, us, rects = built
    eng = ShardedEngine(idx, n_shards=8)
    qlog = QueryLog()
    obs.enable()
    fe = Frontend(eng, max_batch=16, max_delay=1e-3, query_log=qlog)
    try:
        fe.warmup(us[:16], rects[:16])
        futs = [fe.submit(int(u), r) for u, r in zip(us[:16], rects[:16])]
        fe.flush(timeout=60)
        ans = [f.result(timeout=60) for f in futs]
    finally:
        fe.close()
    want = sorted(f.trace_id for f in futs)
    assert len(set(want)) == 16
    # host truth for the same queries
    assert ans == list(idx.query_batch(us[:16], rects[:16]))
    # the cluster fan-out spans carry the batch ids
    tagged = [e for e in obs.TRACER.events()
              if e[0].startswith("cluster.")
              and (e[5] or {}).get("trace_ids")]
    assert tagged, "no cluster spans carried trace ids"
    for e in tagged:
        assert set(e[5]["trace_ids"]) <= set(want)
    # querylog v3: one row per request, joined by trace id
    recs = qlog.records()
    assert sorted(r[I_TRACE_ID] for r in recs) == want
    assert all(r[I_ATTEMPT] >= 0 for r in recs)


def test_retry_and_two_phase_degradation_attribution(built):
    """Injected device failures: last_report names the specific trace
    ids that were retried and then degraded (two_phase target)."""
    _, idx, eng, us, rects = built
    ren = ResilientEngine(
        eng, idx, name="trace-attrib", degraded_path="two_phase",
        retry=RetryPolicy(max_attempts=2, base_s=1e-4, cap_s=1e-3),
        sleep=lambda s: None)
    B = 8
    ctxs = [trace_context.mint(u=int(u)) for u in us[:B]]
    want = [c.trace_id for c in ctxs]
    # exactly the two device attempts fail; the two_phase degradation
    # target crosses the same fault point, so it must stay unpoisoned
    plan = FaultPlan(FaultSpec("engine.query_batch", kind="raise",
                               max_fires=2), seed=5)
    with inject(plan):
        with trace_context.scope(ctxs):
            out = ren.query_batch(us[:B], rects[:B])
    rep = ren.last_report
    assert rep["trace_ids"] == want
    assert rep["retries"] == 1
    assert rep["retried_trace_ids"] == want      # whole batch retried
    assert rep["degraded_trace_ids"] == want     # ... then degraded
    assert rep["degraded"].all()
    assert (rep["attempts"] == 2).all()          # both device attempts
    # degradation is exact: two_phase answers match the host truth
    assert (out == idx.query_batch(us[:B], rects[:B])).all()


def test_partial_failure_attributes_only_failed_ids(built):
    """One poisoned attempt then success: attempts reflects per-query
    device cost and nothing is degraded."""
    _, idx, eng, us, rects = built
    ren = ResilientEngine(
        eng, idx, name="trace-partial",
        retry=RetryPolicy(max_attempts=3, base_s=1e-4, cap_s=1e-3),
        sleep=lambda s: None)
    B = 4
    ctxs = [trace_context.mint(u=int(u)) for u in us[:B]]
    with inject(FaultPlan(FaultSpec("engine.query_batch", kind="raise",
                                    max_fires=1), seed=2)):
        with trace_context.scope(ctxs):
            ren.query_batch(us[:B], rects[:B])
    rep = ren.last_report
    assert rep["retried_trace_ids"] == [c.trace_id for c in ctxs]
    assert rep["degraded_trace_ids"] == []
    assert not rep["degraded"].any()
    assert (rep["attempts"] == 2).all()


def test_dynamic_compaction_swap_preserves_trace_ids():
    """DynamicIndex queries inside a scope keep carrying ids across a
    mid-stream compaction swap (base index replaced under the reader)."""
    from repro.core import build_dynamic_index

    rng = np.random.default_rng(3)
    g = random_geosocial(rng, 60, 160)
    dyn = build_dynamic_index(g, "2dreach-comp")
    us, rects = random_queries(rng, g, 4)
    obs.enable()
    ctxs = [trace_context.mint(u=int(u)) for u in us]
    want = [c.trace_id for c in ctxs]
    with trace_context.scope(ctxs):
        before = [dyn.query(int(u), r) for u, r in zip(us, rects)]
        dyn.add_edge(0, 1)
        assert dyn.compact(background=False)     # swap mid-stream
        after = [dyn.query(int(u), r) for u, r in zip(us, rects)]
    assert dyn.stats["n_compactions"] == 1
    tagged = [e for e in obs.TRACER.events()
              if e[0].startswith("dynamic.")
              and (e[5] or {}).get("trace_ids") == want]
    # probes both before and after the swap carried the ids
    assert len(tagged) >= len(before) + len(after)


# ------------------------------------------------------------- exemplars


def test_exemplar_reservoir_deterministic_under_seeded_stream():
    rng = np.random.default_rng(11)
    vals = rng.lognormal(3.0, 1.0, 2000)
    tids = np.arange(1, 2001)

    def fill(seed):
        h = Histogram("t", exemplar_cap=4, seed=seed)
        for t, v in zip(tids, vals):
            h.record(float(v), exemplar=int(t))
        return h

    a, b = fill(0), fill(0)
    assert a.exemplars() == b.exemplars()        # same seed: identical
    assert a.exemplars()                          # and non-empty
    for bucket, res in a.exemplars().items():
        assert len(res) <= 4
        for tid, v in res:
            assert v == pytest.approx(vals[tid - 1])
    c = fill(1)
    assert c.exemplars().keys() == a.exemplars().keys()


def test_exemplars_near_percentile_and_reset():
    h = Histogram("t", exemplar_cap=2, seed=0)
    for i, v in enumerate([10.0] * 50 + [1e6] * 2):
        h.record(v, exemplar=i)
    near = h.exemplars_near(h.percentile(99))
    assert near and all(v == 1e6 for _t, v in near)
    h.reset()
    assert h.exemplars() == {}


# ----------------------------------- per-thread interval accounting


def _fake_span(tracer, name, t0_ns, dur_ns):
    tracer.record(name, "x", t0_ns, dur_ns, None)


def test_stage_totals_union_per_thread_then_across():
    """Two threads inside the same stage with overlap: the total is the
    union (wall time >=1 thread was in the stage), not the sum."""
    tr = Tracer()
    barrier = threading.Barrier(2)

    def worker(t0, dur):
        barrier.wait()
        _fake_span(tr, "engine.scan", t0, dur)

    ts = [threading.Thread(target=worker, args=(0, 100_000)),
          threading.Thread(target=worker, args=(50_000, 100_000))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    tids = {e[2] for e in tr.events()}
    assert len(tids) == 2
    # [0, 100us] U [50us, 150us] = 150us, not 200us
    assert tr.stage_totals("engine.")["engine.scan"] == \
        pytest.approx(150.0)


def test_stage_totals_sequential_spans_still_sum():
    tr = Tracer()
    _fake_span(tr, "engine.scan", 0, 100_000)
    _fake_span(tr, "engine.scan", 200_000, 100_000)
    assert tr.stage_totals()["engine.scan"] == pytest.approx(200.0)


def test_coverage_capped_under_concurrent_flush_threads():
    """The golden from the field: two flush threads serving overlapping
    batches used to sum to >100% coverage; per-thread union caps it."""
    tr = Tracer()
    done = threading.Barrier(3)

    def worker(t0_ns):
        _fake_span(tr, "frontend.flush", t0_ns, 80_000)
        done.wait()

    a = threading.Thread(target=worker, args=(0,))
    b = threading.Thread(target=worker, args=(40_000,))
    a.start()
    b.start()
    done.wait()
    a.join()
    b.join()
    cov = tr.coverage(0.0, 100_000 / 1e9, prefixes=("frontend.",))
    assert cov <= 1.0
    # union [0,80]+[40,120]->clip[0,100] = 100us of a 100us window
    assert cov == pytest.approx(1.0)
    # one thread alone covers 80%
    assert tr.coverage(0.0, 100_000 / 1e9) == pytest.approx(1.0)


def test_coverage_same_thread_nested_spans_not_double_counted():
    tr = Tracer()
    _fake_span(tr, "engine.outer", 0, 100_000)
    _fake_span(tr, "engine.inner", 10_000, 50_000)   # nested: same thread
    assert tr.coverage(0.0, 100_000 / 1e9) == pytest.approx(1.0)


# -------------------------------------------- timeseries final flush


def test_timeseries_flushes_partial_window_on_dump(tmp_path):
    """A run shorter than one sampling interval still exports its data:
    to_jsonl takes one final sample covering the in-flight window."""
    import json

    from repro.obs.metrics import Registry

    reg = Registry()
    t = [100.0]
    ts = TimeSeriesCollector(registry=reg, interval=60.0,
                             clock=lambda: t[0])
    reg.counter("served").inc(7)
    reg.histogram("lat_us").record(123.0)
    assert ts.dirty()
    path = str(tmp_path / "ts.jsonl")
    ts.to_jsonl(path)
    lines = [json.loads(l) for l in open(path) if l.strip()]
    assert lines[0]["samples"] == 1
    sample = lines[1]
    assert sample["t"] == 100.0
    assert sample["counters"]["served"]["delta"] == 7.0
    assert sample["histograms"]["lat_us"]["delta"] == 1
    # no new activity -> dump again adds no sample (idempotent tail)
    assert not ts.dirty()
    ts.to_jsonl(path)
    lines2 = [json.loads(l) for l in open(path) if l.strip()]
    assert lines2[0]["samples"] == 1


def test_timeseries_dirty_tracks_new_activity():
    from repro.obs.metrics import Registry

    reg = Registry()
    t = [0.0]
    ts = TimeSeriesCollector(registry=reg, clock=lambda: t[0])
    assert not ts.dirty()                # empty registry, no samples
    c = reg.counter("x")
    assert ts.dirty()                    # registered but never sampled
    ts.sample()
    assert not ts.dirty()
    c.inc()
    assert ts.dirty()
    t[0] = 1.0
    ts.sample()
    assert not ts.dirty()
