"""Delta overlay: the mutable side of a `DynamicIndex`.

The overlay absorbs online mutations between compactions:

* **edge buffer** — append-only list of delta edges (src, dst) over both
  base and newly added vertices.
* **spatial staging set** — vertices that acquired a coordinate since the
  last compaction (new venues / check-ins), indexed by its own small
  packed R-tree (rebuilt lazily; the set is bounded by the compaction
  policy so the rebuild is O(overlay), not O(graph)).
* **union-find over condensation components** — DAGGER-style (Yildirim
  et al.) incremental SCC maintenance: when a delta edge (s, t) closes a
  cycle (t already reached s), the two endpoint components collapse into
  one group.  Groups are *sound* (members are mutually reachable in the
  mutated graph) but lazily completed: components strictly inside the
  new cycle merge when a later delta edge touches them.  Queries treat a
  reached group as "every member reached", which is all correctness
  needs.

Elements of the union-find are ``0 .. d_base-1`` for base condensation
components and ``d_base + (v - n_base)`` for vertices added after the
base snapshot (each new vertex starts as its own pseudo-component).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.rtree import RTreeForest, build_forest, intersects


class UnionFind:
    """Union-find with path halving, union by size and explicit group
    member lists (needed to expand "reached group -> reached members")."""

    def __init__(self, n: int) -> None:
        self.parent = list(range(n))
        self.size = [1] * n
        # member lists only materialised for non-singleton groups
        self._members: Dict[int, List[int]] = {}
        self.n_unions = 0

    def add(self) -> int:
        e = len(self.parent)
        self.parent.append(e)
        self.size.append(1)
        return e

    def find(self, x: int) -> int:
        p = self.parent
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        ma = self._members.pop(ra, [ra])
        mb = self._members.pop(rb, [rb])
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]
        self._members[ra] = ma + mb
        self.n_unions += 1
        return True

    def group(self, x: int) -> List[int]:
        """All elements in x's group (x itself when singleton)."""
        return self._members.get(self.find(x), [x])


class SpatialStaging:
    """Per-update spatial staging set with its own small R-tree.

    ``add`` is O(1); the packed tree is rebuilt lazily on the next probe
    (the staging set is small by construction — the compaction policy
    bounds it)."""

    def __init__(self) -> None:
        self.ids: List[int] = []
        self.xs: List[float] = []
        self.ys: List[float] = []
        self._id_set: set = set()
        self._forest: Optional[RTreeForest] = None
        self._dirty = False

    def __len__(self) -> int:
        return len(self.ids)

    def __contains__(self, v: int) -> bool:
        return int(v) in self._id_set

    def add(self, v: int, x: float, y: float) -> None:
        self.ids.append(int(v))
        self.xs.append(float(x))
        self.ys.append(float(y))
        self._id_set.add(int(v))
        self._dirty = True

    def coords_of(self) -> np.ndarray:
        return np.stack(
            [np.asarray(self.xs, np.float32), np.asarray(self.ys, np.float32)],
            axis=1,
        ) if self.ids else np.zeros((0, 2), np.float32)

    def _tree(self) -> Optional[RTreeForest]:
        if self._dirty:
            pts = self.coords_of()
            boxes = np.concatenate([pts, pts], axis=1)
            self._forest = build_forest(
                boxes,
                np.asarray(self.ids, np.int32),
                np.zeros(len(self.ids), np.int64),
                n_trees=1,
            )
            self._dirty = False
        return self._forest

    def candidates_in(self, rect: np.ndarray) -> np.ndarray:
        """Staged vertex ids whose coordinate lies inside ``rect``."""
        if not self.ids:
            return np.zeros(0, dtype=np.int32)
        forest = self._tree()
        rect = np.asarray(rect, dtype=np.float32)
        s, e = forest.entry_off[0], forest.entry_off[1]
        ok = intersects(forest.entries[s:e], rect, dim=2)
        return forest.entry_ids[s:e][ok]

    def nbytes(self) -> int:
        fixed = 16 * len(self.ids)  # id + 2 coords + slack
        return fixed + (self._forest.nbytes_total() if self._forest else 0)


class DeltaOverlay:
    """Mutable overlay state between two compactions."""

    def __init__(self, n_base: int, d_base: int) -> None:
        self.n_base = n_base          # vertices in the base snapshot
        self.d_base = d_base          # components in the base condensation
        self.n_nodes = n_base         # grows with add_vertex
        self.edges: List[Tuple[int, int]] = []
        self.staging = SpatialStaging()
        self.uf = UnionFind(d_base)
        self.n_scc_merges = 0

    # -- element mapping ---------------------------------------------------
    def elem_of_vertex(self, v: int, base_comp: np.ndarray) -> int:
        """Union-find element for vertex v."""
        if v < self.n_base:
            return int(base_comp[v])
        return self.d_base + (v - self.n_base)

    def add_vertex(self) -> int:
        v = self.n_nodes
        self.n_nodes += 1
        self.uf.add()
        return v

    def add_edge(self, s: int, t: int) -> None:
        self.edges.append((int(s), int(t)))

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    @property
    def n_staged(self) -> int:
        return len(self.staging)

    @property
    def n_new_vertices(self) -> int:
        return self.n_nodes - self.n_base

    def is_empty(self) -> bool:
        return not self.edges and not len(self.staging) \
            and self.n_nodes == self.n_base

    def nbytes(self) -> int:
        return 16 * len(self.edges) + self.staging.nbytes() \
            + 16 * len(self.uf.parent)
