"""End-to-end driver: a RangeReach serving node (the paper's workload).

Phase 1 (static): builds the 2DReach-Comp index over a Yelp-shaped
graph, verifies the four query engines against each other and the
oracle, then serves batched request streams and reports
latency/throughput per engine — host wavefront, jit wavefront, the
Pallas leaf-scan kernel, and the compile-once device QueryEngine
(fused pointer lookup + hierarchically-pruned descent; interpret mode
on CPU, the same calls compile to real kernels on TPU).

Phase 1b (analytics): the same compiled engine answers the richer
geosocial query classes of `repro.queries` — RangeCount, RangeCollect,
KNNReach and convex-polygon regions — exact, bit-identical host vs
device, spot-checked against the BFS oracles.

Phase 2 (cluster): partitions the same forest into 8 shards
(`repro.cluster.ShardedEngine`) and serves it request-at-a-time through
the deadline-or-full micro-batching `Frontend`, asserting answers stay
bit-identical to the host and that steady state recompiles nothing.

Phase 3 (dynamic): wraps the same graph in a DynamicIndex and serves a
*mutating* stream — new users, follows and check-ins interleaved with
queries — answering every query on the mutated graph without a rebuild,
with answers spot-checked against the BFS oracle, then compacts
(background thread) and verifies the post-swap index again.

    PYTHONPATH=src python examples/serve_rangereach.py
"""

import time

import numpy as np

from repro.core import (
    batch_query,
    build_dynamic_index,
    build_index,
    engine_for,
    query_host,
    query_jax_wavefront,
    rangereach_oracle_batch,
)
from repro.data import apply_stream_op, get_dataset, streaming_workload, workload
from repro.dynamic import CompactionPolicy
from repro.kernels.range_query.ops import range_query_forest

g = get_dataset("yelp", scale=0.2)
print(f"[build] yelp x0.2: {g.n_nodes} nodes, {g.n_edges} edges")
t0 = time.perf_counter()
index = build_index(g, "2dreach-comp")
print(f"[build] 2dreach-comp in {time.perf_counter() - t0:.2f}s, "
      f"{int(index.stats['distinct_rtrees'])} distinct R-trees")

# ----- request stream ------------------------------------------------------
BATCHES = 10
BATCH = 256
engine = engine_for(index)   # one-time device upload (compile-once serving)
lat = {"host": [], "wavefront": [], "kernel": [], "device": []}
for b in range(BATCHES):
    us, rects = workload(g, BATCH, extent_ratio=0.05, seed=100 + b)
    tid = index.lookup_tree(us)
    spatialq = index.excluded[us]

    t0 = time.perf_counter()
    host = query_host(index.forest, tid, rects)
    lat["host"].append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    wf, ovf = query_jax_wavefront(index.forest, tid, rects)
    lat["wavefront"].append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    kr = range_query_forest(index.forest, tid, rects)
    lat["kernel"].append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    dv = engine.query_batch(us, rects)
    lat["device"].append(time.perf_counter() - t0)

    assert not ovf.any()
    assert (host == wf).all() and (host == kr).all(), "engine mismatch"
    full = batch_query(index, us, rects)
    assert (dv == full).all(), "device engine mismatch"  # incl. Alg. 2 case
    if b == 0:  # full-pipeline (Alg. 2) answers vs oracle
        want = rangereach_oracle_batch(g, us[:64], rects[:64])
        assert (full[:64] == want).all()
        print("[verify] engines agree; oracle check OK")
print(f"[serve] device engine: {engine.n_compiles} compiled shapes "
      f"(steady state), {engine.stats['tiles_scanned']}"
      f"/{engine.stats['tiles_full_scan']} leaf tiles scanned")

for name, ts in lat.items():
    ts = np.array(ts[1:])  # drop warmup/compile batch
    print(f"[serve] {name:<10} p50 {np.median(ts) / BATCH * 1e6:7.2f} "
          f"us/query   p max {ts.max() / BATCH * 1e6:7.2f} us/query "
          f"({BATCHES - 1} batches x {BATCH})")

# ----- analytics query classes (count / collect / kNN / polygon) -----------
# the same compiled engine answers the richer geosocial classes of
# repro.queries — exact, device bit-identical to host, oracle spot-checked.
# Equivalent CLI:  python -m repro.launch.serve --query-class knn --engine device
from repro.core import run_queries
from repro.core.oracle import (
    knn_reach_oracle,
    polygon_reach_oracle,
    range_collect_oracle,
    range_count_oracle,
)
from repro.data import knn_workload, polygon_workload
from repro.queries import QueryProgram

print("\n[analytics] count / collect / kNN / polygon on the device engine")
K = 8
aus, arects = workload(g, 256, extent_ratio=0.05, seed=300)
kus, kpts = knn_workload(g, 256, seed=301)
pus, ppolys = polygon_workload(g, 256, extent_ratio=0.05, seed=302)
programs = {
    "count": QueryProgram.count(aus, arects),
    "collect": QueryProgram.collect(aus, arects, K),
    "knn": QueryProgram.knn(kus, kpts, K),
    "polygon": QueryProgram.polygon(pus, ppolys),
}
host_answers = {}
for kind, prog in programs.items():
    host_ans = host_answers[kind] = run_queries(index, prog, engine="host")
    dev_ans = run_queries(index, prog, engine="device")   # warm / compile
    t0 = time.perf_counter()
    run_queries(index, prog, engine="device")
    dt = time.perf_counter() - t0
    if kind in ("count", "polygon"):
        assert (dev_ans == host_ans).all(), f"{kind}: device != host"
        tail = f"{int(np.sum(host_ans))} " + (
            "total hits" if kind == "count" else "positive")
    elif kind == "collect":
        assert (dev_ans.ids == host_ans.ids).all()
        assert (dev_ans.counts == host_ans.counts).all()
        tail = (f"{int(host_ans.counts.sum())} venues materialised, "
                f"{int(host_ans.overflow.sum())} overflowed K={K}")
    else:
        assert (dev_ans.ids == host_ans.ids).all()
        assert (dev_ans.dist2 == host_ans.dist2).all()
        tail = f"{int((host_ans.ids >= 0).sum())} neighbours returned"
    print(f"[analytics] {kind:<8} device == host "
          f"({dt / prog.n_queries * 1e6:7.2f} us/query warm)  {tail}")
# oracle spot-check across all four classes (host answers from above)
cnt_h, col_h = host_answers["count"], host_answers["collect"]
knn_h, pol_h = host_answers["knn"], host_answers["polygon"]
for b in range(16):
    assert cnt_h[b] == range_count_oracle(g, int(aus[b]), arects[b])
    want = range_collect_oracle(g, int(aus[b]), arects[b])
    assert col_h.counts[b] == len(want) and (col_h.row(b) == want[:K]).all()
    oi, _ = knn_reach_oracle(g, int(kus[b]), kpts[b], K)
    assert (knn_h.row(b) == oi).all()
    assert pol_h[b] == polygon_reach_oracle(g, int(pus[b]), ppolys[b])
print("[analytics] oracle spot-check OK on all four classes")

# ----- cluster serving (sharded engine + micro-batching frontend) ----------
# the same forest, partitioned into 8 shards (stacked per device when the
# host exposes fewer than 8) and served request-at-a-time through the
# deadline-or-full frontend — equivalent CLI:
#   python -m repro.launch.serve --engine cluster --shards 8
from repro.cluster import Frontend, ShardedEngine

ceng = ShardedEngine(index, n_shards=8)
print(f"\n[cluster] {ceng.n_shards} shards on "
      f"{ceng.mesh.shape['data']} device(s), per-shard entries "
      f"{ceng.partition.shard_entries.tolist()}")
us, rects = workload(g, 512, extent_ratio=0.05, seed=200)
want = batch_query(index, us, rects)
with Frontend(ceng, max_batch=128, max_delay=2e-3) as fe:
    fe.warmup(us[:128], rects[:128])
    fe.submit_many(us, rects)          # warm pass fixes the K mark
    fe.warmup(us[:128], rects[:128])   # re-pin every bucket at that mark
    warm = ceng.n_compiles
    t0 = time.perf_counter()
    got = fe.submit_many(us, rects)
    dt = time.perf_counter() - t0
    assert (got == want).all(), "cluster engine mismatch"
    assert ceng.n_compiles == warm, "steady-state recompile under frontend"
    print(f"[cluster] {len(us)} queries in {dt * 1e3:.1f} ms "
          f"({dt / len(us) * 1e6:.2f} us/query), "
          f"{int(fe.stats['n_batches'])} flushes "
          f"(full {int(fe.stats['n_flush_full'])} / deadline "
          f"{int(fe.stats['n_flush_deadline'])}), "
          f"routing {ceng.shard_queries.tolist()}")
    print(f"[cluster] answers match host; {ceng.n_compiles} compiled "
          f"shapes stayed flat through the steady-state pass")

# ----- mutating stream (phase 3) -------------------------------------------
print("\n[dynamic] serving a mutating stream (updates + queries interleaved)")
dyn = build_dynamic_index(
    g, "2dreach-comp", engine="device",   # device base probe, host overlay
    policy=CompactionPolicy(max_overlay_edges=4096, background=True),
)
STEPS = 4000
VERIFY_EVERY = 500   # oracle spot-check cadence (BFS on the mutated graph)
pending_us, pending_rects, q_lat = [], [], []
n_updates = n_queries = 0
for step, op in enumerate(streaming_workload(
        g, n_steps=STEPS, seed=17,
        p_query=0.5, p_edge=0.3, p_vertex=0.1, p_spatial=0.1)):
    pending = apply_stream_op(dyn, op)
    if pending is None:
        n_updates += 1
    else:
        pending_us.append(pending[0])
        pending_rects.append(pending[1])
        if len(pending_us) == 64:  # serve in small batches
            us_b = np.asarray(pending_us)
            rects_b = np.asarray(pending_rects, np.float32)
            t0 = time.perf_counter()
            dyn.query_batch(us_b, rects_b)
            q_lat.append((time.perf_counter() - t0) / len(us_b))
            n_queries += len(us_b)
            pending_us, pending_rects = [], []
    if step and step % VERIFY_EVERY == 0:
        gm = dyn.snapshot_graph()
        vu, vr = workload(gm, 32, extent_ratio=0.05, seed=step)
        assert (dyn.query_batch(vu, vr)
                == rangereach_oracle_batch(gm, vu, vr)).all(), \
            f"dynamic answers diverged from oracle at step {step}"
        print(f"[dynamic] step {step:5d}: overlay={dyn.overlay_size:5d} "
              f"p50 {np.median(q_lat) * 1e6:7.2f} us/query  oracle OK")

if pending_us:  # flush the trailing partial batch
    dyn.query_batch(np.asarray(pending_us), np.asarray(pending_rects, np.float32))
    n_queries += len(pending_us)

# force a final compaction swap and verify the rebuilt base
dyn.compact(background=True)
dyn.join_compaction()
gm = dyn.snapshot_graph()
vu, vr = workload(gm, 64, extent_ratio=0.05, seed=999)
assert (dyn.query_batch(vu, vr) == rangereach_oracle_batch(gm, vu, vr)).all()
rep = dyn.report()
print(f"[dynamic] {n_updates} updates, {n_queries} queries, "
      f"{int(rep['n_compactions'])} compactions "
      f"({rep['t_compaction_total']:.2f}s total, "
      f"{rep.get('amortized_compaction_us_per_update', 0.0):.1f} "
      f"us/update amortized), {int(rep['n_scc_merges'])} SCC merges")
print(f"[dynamic] post-swap verify OK on {gm.n_nodes} nodes "
      f"({gm.n_nodes - g.n_nodes} added), {gm.n_edges} edges")
