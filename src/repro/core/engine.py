"""Device-resident RangeReach query engine (compile-once serving).

The paper's pitch is that a 2DReach query "reduces to a single 2D R-tree
lookup" — but a lookup that round-trips through host NumPy per batch
(pointer gather on CPU, forest re-transposed to SoA per call, every leaf
scanned) forfeits the reduction.  :class:`QueryEngine` uploads a built
:class:`~repro.core.two_d_reach.TwoDReachIndex` to the accelerator
**once** and answers ``query_batch`` entirely on device:

The default serving path is the **fused megakernel**
(:mod:`repro.kernels.range_query.fused`): ONE dispatch per batch that
routes vertex→tree in-trace, prunes against *quantized* tile-MBR planes
(int16 fine / int32 coarse, outward-rounded so the candidate set
provably contains the f32 truth), compacts the surviving tiles into an
in-kernel worklist, and scans them with the exact f32 leaf predicate —
the boolean / count / collect epilogues share the trace via a mode
flag, so ``query_batch`` / ``count_batch`` / ``collect_batch`` all ride
one kernel with no prune→host→scan round trip.  Batches are padded to
power-of-two buckets by an on-device :class:`DevicePadder` (donated
per-bucket buffers, no host re-stack), and the candidate capacity K is
a monotone high-water mark: an overflowing batch re-runs once at the
ratcheted capacity, so steady-state serving recompiles nothing —
asserted by tests via jit cache-size introspection.

The pre-fusion **two-phase** path is retained in full — reachable via
``path="two_phase"`` or the ``*_two_phase`` methods — as the oracle the
fused path is bit-compared against, as the
:class:`~repro.resilience.engine.ResilientEngine` degradation target,
and as the host of the polygon class (whose half-plane scan is not
fused):

1. **fused pointer lookup** — vertex→tree inside the jit: a plain
   gather for the base/comp variants, or the Pointer variant's
   bit-vector + rank structure evaluated with an in-jit SWAR popcount;
   spatial-sink queries (Alg. 2's special case) fuse to a point-in-rect
   test in the same trace;
2. **hierarchical prune** — the Pallas ``prune_tiles`` kernel ANDs each
   query rect against internal-level tile MBRs (coarse gate + fine
   test, see :mod:`repro.kernels.range_query.descent`) to decide which
   leaf tiles each query tile actually needs;
3. **masked descent scan** — the scalar-prefetch ``descent_scan``
   kernel visits only the compacted candidate tiles, so work scales
   with the query's R-tree footprint instead of the arena size.

Exactness never rests on the pruning (quantized or f32): the scan
re-masks by arena slice and exact box test, so both paths are
bit-identical to the ``query_host`` oracle (scanning an extra tile is
an idempotent OR with no new hits).

The upload path is factored into two reusable pieces so the sharded
cluster engine (:mod:`repro.cluster`) serves the same structures:

* :class:`PointerSide` — the replicated vertex→tree lookup arrays plus
  the fused in-jit routing (lookup + Alg. 2 forced answers);
* :class:`TileArena` — one SoA entry arena + tile-MBR pyramid (a shard
  holds one arena; the single-device engine holds the whole forest's).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.range_query.analytics import (
    ID_SENTINEL,
    collect_scan_pallas,
    count_scan_pallas,
    polygon_scan_pallas,
)
from ..kernels.range_query.descent import (
    build_tile_pyramid,
    descent_scan_pallas,
    prune_tiles_pallas,
)
from ..kernels.range_query.fused import (
    compact_ascending,
    fused_serve_pallas,
    fused_serve_xla,
    make_quant_grid,
    quantize_coarse,
    quantize_fine,
    quantize_rects,
)
from ..kernels.range_query.kernel import TB, TP
from ..kernels.range_query.ops import forest_soa
from ..obs import CounterDict, REGISTRY, span
from ..obs.tracer import TRACER as _TRACER
from ..resilience.faults import fault_point, fault_value
from .polygon import convex_halfplanes, points_in_polygon_region, polygon_bbox
from .two_d_reach import TwoDReachIndex


def _bucket(n: int, lo: int) -> int:
    """Smallest power-of-two >= max(n, lo) (lo itself a power of two)."""
    b = lo
    while b < n:
        b <<= 1
    return b


def _collect_post(mat: jax.Array, *, kc: int):
    """Fused collect postprocess: (B, K*TP) ids-or-sentinel -> the
    ``kc`` smallest ids per row (sentinel sorts last) + exact totals."""
    srt = jnp.sort(mat, axis=1)
    cnt = jnp.sum(mat != ID_SENTINEL, axis=1)
    return srt[:, :kc], cnt


def _popcount32_jnp(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(jnp.int32)


# --------------------------------------------------------------------------
# Reusable upload pieces (single-device engine + cluster shards)
# --------------------------------------------------------------------------

# Build→serve handoff counters since import.  ``host_uploads`` counts
# arenas built from host arrays (transpose + pyramid + upload);
# ``device_adoptions`` counts arenas adopted zero-copy from a
# ``build_forest_device`` handoff.  Benchmarks and tests assert that
# serving a device-built index — including every DynamicIndex compaction
# swap — bumps only the adoption counter.  The values live in the
# ``repro.obs`` metrics registry (``engine.upload.*``); this dict-shaped
# view keeps the legacy ``UPLOAD_COUNTERS[...]`` surface working.
UPLOAD_COUNTERS = CounterDict(
    "engine.upload.", ("host_uploads", "device_adoptions"))

class PointerSide:
    """Device-resident vertex→tree lookup side of a 2DReach index.

    Holds the arrays every serving replica needs in full — coords,
    excluded mask, and the variant's pointer structure — and evaluates
    the fused lookup / Alg. 2 routing inside whatever jit traces it.
    In the cluster engine these arrays are *replicated* per device while
    the R-tree arenas shard.
    """

    def __init__(self, index: TwoDReachIndex):
        self.variant = index.variant
        self.dim = index.forest.dim
        self._coords = jnp.asarray(index.coords, jnp.float32)
        self._excluded = jnp.asarray(index.excluded)
        if self.variant == "pointer":
            self._vertex_comp = jnp.asarray(index.vertex_comp, jnp.int32)
            self._bits = jnp.asarray(index.bitrank.bits)
            self._rank = jnp.asarray(index.bitrank.rank, jnp.int32)
            self._tree_ptrs = jnp.asarray(index.tree_ptrs, jnp.int32)
            self._vertex_tree = None
        else:
            self._vertex_tree = jnp.asarray(index.vertex_tree, jnp.int32)

    def lookup(self, us: jax.Array) -> jax.Array:
        """Fused vertex -> tree id (-1: excluded / no tree), in-jit."""
        if self.variant != "pointer":
            return self._vertex_tree[us]
        c = self._vertex_comp[us]
        ok = c >= 0
        cc = jnp.maximum(c, 0)
        w = cc // 32
        b = (cc % 32).astype(jnp.uint32)
        word = self._bits[w]
        member = ((word >> b) & np.uint32(1)) > 0
        below = word & ((np.uint32(1) << b) - np.uint32(1))
        rank = self._rank[w] + _popcount32_jnp(below)
        t = self._tree_ptrs[
            jnp.minimum(rank, self._tree_ptrs.shape[0] - 1)
        ]
        return jnp.where(ok & member, t, -1)

    def route(self, us: jax.Array, rects_soa: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """(tree id, needs-tree-probe mask, Alg. 2 forced answers).

        ``forced`` is the spatial-query special case fused in-trace: an
        excluded (spatial-sink) query vertex answers by its own point
        against the rect, with the same float32 comparisons as host.
        """
        dim = self.dim
        tid = self.lookup(us)
        exc = self._excluded[us]
        valid = (tid >= 0) & ~exc
        pt = self._coords[us]
        inr = jnp.ones(us.shape[0], dtype=bool)
        for a in range(dim):
            inr = inr & (pt[:, a] >= rects_soa[a])
            inr = inr & (pt[:, a] <= rects_soa[dim + a])
        return tid, valid, exc & inr


@dataclasses.dataclass(frozen=True)
class TileArena:
    """One uploaded SoA entry arena + its tile-MBR pyramid."""

    entries: jax.Array     # (2*dim, Pp) float32 SoA planes
    fine: jax.Array        # (2*dim, NTp) float32 leaf-tile MBRs
    coarse: jax.Array      # (2*dim, NTp // COARSE_GROUP) float32
    entry_off: jax.Array   # (T+1,) int32 per-tree arena slices
    n_tiles: int           # true fine tile count (Pp // TP)

    @classmethod
    def upload(cls, esoa: np.ndarray, off: np.ndarray,
               dim: int) -> "TileArena":
        UPLOAD_COUNTERS["host_uploads"] += 1
        with span("engine.soa_upload", cat="build",
                  nbytes=int(esoa.nbytes)):
            fine, coarse, nt = build_tile_pyramid(esoa, dim)
            return cls(
                entries=jnp.asarray(esoa),
                fine=jnp.asarray(fine),
                coarse=jnp.asarray(coarse),
                entry_off=jnp.asarray(off, jnp.int32),
                n_tiles=nt,
            )

    @classmethod
    def for_forest(cls, forest, dim: int) -> "TileArena":
        """Arena for a built forest — adopted zero-copy when the forest
        carries a ``build_forest_device`` handoff (the arrays are
        already device-resident in exactly this layout), uploaded from
        the host arrays otherwise."""
        dev = getattr(forest, "device", None)
        if dev is not None:
            UPLOAD_COUNTERS["device_adoptions"] += 1
            return cls(
                entries=dev.entries,
                fine=dev.fine,
                coarse=dev.coarse,
                entry_off=dev.entry_off,
                n_tiles=dev.n_tiles,
            )
        esoa, off = forest_soa(forest)        # cached transposition
        return cls.upload(esoa, off, dim)


def compact_candidates(mask: jax.Array, nt: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Prune mask (NB, >=nt) -> compacted candidate tiles per query tile.

    Returns ``(cand (NB, nt) int32, cnt (NB,) int32)``: active tiles
    first (ascending), then the last active tile repeated so consecutive
    identical block indices elide the scan kernel's DMA.  (Delegates to
    the fused module's :func:`compact_ascending` — one definition shared
    by the two-phase path, the fused XLA path, and the cluster engine.)
    """
    return compact_ascending(mask, nt)


def pad_batch(us: np.ndarray, rects: np.ndarray, dim: int
              ) -> Tuple[int, np.ndarray, np.ndarray]:
    """Pad a host batch to its power-of-two bucket.

    Returns ``(Bb, us_p (Bb,) int32, rsoa (2*dim, Bb) float32)``.
    Padding rects must miss every box regardless of data extent:
    min=+inf / max=-inf fails both halves of the intersect test (a
    finite 1.0/0.0 sentinel would phantom-hit tiles spanning it).
    """
    B = len(us)
    rects = np.asarray(rects, dtype=np.float32).reshape(B, 2 * dim)
    Bb = _bucket(B, TB)
    us_p = np.zeros(Bb, dtype=np.int32)
    us_p[:B] = us
    rsoa = np.empty((2 * dim, Bb), dtype=np.float32)
    rsoa[:dim] = np.inf
    rsoa[dim:] = -np.inf
    rsoa[:, :B] = rects.T
    return Bb, us_p, rsoa


class DevicePadder:
    """Device-resident batch padding — kills the host ``pad_batch``
    re-stack on the serving hot path.

    Keeps, per power-of-two bucket, a pinned host *staging* pair plus a
    donated device buffer pair.  A batch copies only its true-B prefix
    into the staging arrays (no allocation, no tail memset — O(B) host
    work instead of the old full-bucket re-stack), uploads the
    bucket-shaped staging, and the fill jit masks the stale tail inert
    on-device with an iota-vs-live-count compare (``us=0``, rect
    min=+inf / max=-inf), so a larger previous batch can never leak
    rects into a smaller one's padding.  The live count enters the
    trace as a *dynamic* scalar and every array input is bucket-shaped,
    so the fill trace is keyed on the bucket alone — any unseen true B
    inside a warmed bucket is compile-free.  The jit *donates* the
    bucket's device buffers and the outputs are stored back as the next
    batch's donation inputs (serving consumes a batch's rects strictly
    before the same bucket pads again, so the aliasing is safe), which
    lets XLA write each fill into the existing allocation.  The cache
    size feeds the engine's ``n_compiles`` introspection.
    """

    def __init__(self, dim: int):
        self.dim = dim
        self._bufs: Dict[int, Tuple[jax.Array, jax.Array]] = {}
        self._stage: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

        def fill(us_buf, r_buf, us_stage, r_stage, b):
            Bb = us_buf.shape[0]
            live = jnp.arange(Bb, dtype=jnp.int32) < b
            us_o = jnp.where(live, us_stage, 0)
            inert = jnp.concatenate([
                jnp.full((dim, Bb), jnp.inf, jnp.float32),
                jnp.full((dim, Bb), -jnp.inf, jnp.float32)])
            r_o = jnp.where(live[None, :], r_stage, inert)
            return us_o, r_o

        self._fill = jax.jit(fill, donate_argnums=(0, 1))

    def _cache_size(self) -> int:
        return self._fill._cache_size()

    def pad(self, us: np.ndarray, rects: np.ndarray
            ) -> Tuple[int, jax.Array, jax.Array]:
        """Pad to the pow2 bucket on-device.  Returns ``(Bb, us_dev
        (Bb,) int32, rsoa_dev (2*dim, Bb) float32)`` — same contents as
        ``pad_batch`` would produce, already device-resident."""
        B = len(us)
        Bb = _bucket(B, TB)
        stage = self._stage.get(Bb)
        if stage is None:
            stage = self._stage[Bb] = (
                np.zeros(Bb, np.int32),
                np.zeros((2 * self.dim, Bb), np.float32))
        us_s, r_s = stage
        us_s[:B] = us
        r_s[:, :B] = np.asarray(
            rects, dtype=np.float32).reshape(B, 2 * self.dim).T
        bufs = self._bufs.get(Bb)
        if bufs is None:
            rs0 = np.empty((2 * self.dim, Bb), np.float32)
            rs0[: self.dim] = np.inf
            rs0[self.dim:] = -np.inf
            bufs = (jnp.zeros(Bb, jnp.int32), jnp.asarray(rs0))
        us_b, r_b = self._fill(bufs[0], bufs[1], jnp.asarray(us_s),
                               jnp.asarray(r_s), np.int32(B))
        self._bufs[Bb] = (us_b, r_b)
        return Bb, us_b, r_b


# --------------------------------------------------------------------------
# Single-device engine
# --------------------------------------------------------------------------

class QueryEngine:
    """Compile-once device engine over a built ``TwoDReachIndex``.

    Parameters
    ----------
    index:     any 2DReach variant (``base`` / ``comp`` / ``pointer``).
    interpret: run the Pallas kernels in interpret mode; ``None`` picks
               real kernels on TPU and interpret elsewhere.
    path:      ``"fused"`` (default) serves reach/count/collect through
               the single-launch fused kernel; ``"two_phase"`` forces
               the retained prune→compact→scan reference path.
    fused_impl: ``"pallas"`` (the megakernel) or ``"xla"`` (the fused
               XLA program, bit-identical); ``None`` picks the
               megakernel on TPU and the XLA program elsewhere (one
               compiled XLA dispatch beats an interpreted kernel on
               CPU).
    """

    def __init__(self, index: TwoDReachIndex,
                 interpret: Optional[bool] = None,
                 path: str = "fused",
                 fused_impl: Optional[str] = None):
        if not isinstance(index, TwoDReachIndex):
            raise TypeError(
                f"QueryEngine serves TwoDReachIndex, got {type(index).__name__}"
            )
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if path not in ("fused", "two_phase"):
            raise ValueError(f"unknown engine path {path!r}")
        if fused_impl is None:
            fused_impl = "pallas" if jax.default_backend() == "tpu" else "xla"
        if fused_impl not in ("pallas", "xla"):
            raise ValueError(f"unknown fused impl {fused_impl!r}")
        self._interpret = bool(interpret)
        self.path = path
        self._fused_impl = fused_impl
        self.variant = index.variant
        self.dim = index.forest.dim
        self._index = index        # host mirror (KNN exact top-up)

        # ---- one-time upload (or zero-copy adoption) -------------------
        self._side = PointerSide(index)
        self._arena = TileArena.for_forest(index.forest, self.dim)
        self.n_tiles = self._arena.n_tiles

        # host-side routing mirrors + payload-id plane for the analytics
        # classes (count/collect/kNN/polygon, see repro.queries): the id
        # plane rides next to the entry arena (sentinel padding so misses
        # sort last), the excluded/coords mirrors resolve the Alg. 2
        # special case per class
        self._excluded_host = index.excluded
        self._coords_host = index.coords
        Pp = int(self._arena.entries.shape[1])
        ids_row = np.full((1, Pp), ID_SENTINEL, dtype=np.int32)
        ids_row[0, : len(index.forest.entry_ids)] = index.forest.entry_ids
        self._ids_row = jnp.asarray(ids_row)
        ent = index.forest.entries
        self._extent_host = (
            np.concatenate([ent[:, : self.dim].min(0),
                            ent[:, self.dim:].max(0)]).astype(np.float64)
            if len(ent) else None
        )

        # quantized MBR planes for the fused path: int16 fine / int32
        # coarse codes over the arena extent, rounded outward so the
        # quantized candidate set provably contains the f32 truth
        self._grid = make_quant_grid(self._extent_host, self.dim)
        self._qfine = quantize_fine(self._grid, self._arena.fine, self.dim)
        self._qcoarse = quantize_coarse(
            self._grid, self._arena.coarse, self.dim)

        self.stats: Dict[str, float] = {
            "uploads": 1, "batches": 0, "queries": 0,
            "adopted": int(getattr(index.forest, "device", None) is not None),
            "tiles_scanned": 0, "tiles_grid": 0, "tiles_full_scan": 0,
            "fused_reruns": 0,
        }
        # candidate-capacity high-water mark: K only ratchets up, so a
        # smaller batch never traces a new K shape and lifetime scan
        # retraces are bounded by log2(n_tiles) per batch bucket; extra
        # K columns repeat the last candidate tile, whose DMA the
        # pipeline elides
        self._kb_hwm = 1
        self._padder = DevicePadder(self.dim)
        route = self._make_route()
        serve = self._make_routed_serve()

        def fused(us, rects_soa, *, mode, kcap, kc=None):
            qs, qe, pts, exc = route(us)
            return serve(rects_soa, qs, qe, pts, exc, mode=mode,
                         kcap=kcap, kc=kc)

        self._fused = jax.jit(fused, static_argnames=("mode", "kcap", "kc"))
        self._route = jax.jit(route)
        self._fused_routed = jax.jit(
            serve, static_argnames=("mode", "kcap", "kc"))
        self._prepare = jax.jit(self._make_prepare())
        self._scan = jax.jit(self._make_scan())
        self._count_scan = jax.jit(self._make_count_scan())
        self._collect_scan = jax.jit(self._make_collect_scan())
        self._collect_post = jax.jit(_collect_post, static_argnames=("kc",))
        self._polygon_scan = jax.jit(self._make_polygon_scan(),
                                     static_argnames=("ne",))

    # ------------------------------------------------------------------
    # jit closures (per-engine, so cache introspection is local)
    # ------------------------------------------------------------------

    def _make_route(self):
        """Vertex -> (arena slice, point, excluded) routing: the
        rect-independent half of the fused trace, also jitted alone so
        the KNN radius-doubling driver hoists it out of its loop."""
        side = self._side
        arena = self._arena

        def route(us):
            tid = side.lookup(us)
            exc = side._excluded[us]
            valid = (tid >= 0) & ~exc
            t = jnp.maximum(tid, 0)
            qs = jnp.where(valid, arena.entry_off[t], 0)
            qe = jnp.where(valid, arena.entry_off[t + 1], 0)
            return qs, qe, side._coords[us], exc

        return route

    def _make_routed_serve(self):
        """The fused serve body with routing state as explicit inputs:
        quantize rects outward, then one fused prune+compact+scan launch
        (megakernel or the bit-identical XLA program).  Returns
        ``(forced, out, cnt, cnt.max())`` — ``mx > kcap`` means the scan
        truncated and the driver must ratchet and re-run."""
        dim = self.dim
        nt = self.n_tiles
        interpret = self._interpret
        impl = self._fused_impl
        arena = self._arena
        grid = self._grid
        qf, qc = self._qfine, self._qcoarse
        ids_row = self._ids_row

        def serve(rects_soa, qs, qe, pts, exc, *, mode, kcap, kc=None):
            inr = jnp.ones(rects_soa.shape[1], dtype=bool)
            for a in range(dim):
                inr = inr & (pts[:, a] >= rects_soa[a])
                inr = inr & (pts[:, a] <= rects_soa[dim + a])
            forced = exc & inr               # Alg. 2 spatial-sink case
            r16, r32 = quantize_rects(grid, rects_soa, dim)
            if impl == "pallas":
                out, cnt = fused_serve_pallas(
                    qf, qc, arena.entries, ids_row, r16, r32, rects_soa,
                    qs, qe, mode=mode, kcap=kcap, nt=nt, dim=dim,
                    interpret=interpret)
            else:
                out, cnt = fused_serve_xla(
                    qf, qc, arena.entries, ids_row, r16, r32, rects_soa,
                    qs, qe, mode=mode, kcap=kcap, nt=nt, dim=dim)
            if mode == "collect" and kc is not None:
                # collect epilogue inside the same trace: top-kc ids +
                # exact totals, so the host never receives the full
                # (Bb, kcap*TP) id matrix and collect stays one dispatch
                out = _collect_post(out, kc=kc)
            return forced, out, cnt, cnt.max()

        return serve

    def _make_prepare(self):
        nt = self.n_tiles
        interpret = self._interpret
        dim = self.dim
        side = self._side
        arena = self._arena

        def prepare(us, rects_soa):
            # us (Bb,) int32; rects_soa (2*dim, Bb) f32
            tid, valid, forced = side.route(us, rects_soa)
            t = jnp.maximum(tid, 0)
            qs = jnp.where(valid, arena.entry_off[t], 0)
            qe = jnp.where(valid, arena.entry_off[t + 1], 0)
            mask = prune_tiles_pallas(
                arena.fine, arena.coarse, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )
            cand, cnt = compact_candidates(mask, nt)
            return forced, qs, qe, cand, cnt, cnt.max()

        return prepare

    def _make_scan(self):
        dim = self.dim
        interpret = self._interpret
        arena = self._arena

        def scan(cand_k, rects_soa, qs, qe):
            return descent_scan_pallas(
                cand_k, arena.entries, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )

        return scan

    def _make_count_scan(self):
        dim = self.dim
        interpret = self._interpret
        arena = self._arena

        def scan(cand_k, rects_soa, qs, qe):
            return count_scan_pallas(
                cand_k, arena.entries, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )

        return scan

    def _make_collect_scan(self):
        dim = self.dim
        interpret = self._interpret
        arena = self._arena
        ids_row = self._ids_row

        def scan(cand_k, rects_soa, qs, qe):
            return collect_scan_pallas(
                cand_k, arena.entries, ids_row, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )

        return scan

    def _make_polygon_scan(self):
        dim = self.dim
        interpret = self._interpret
        arena = self._arena

        def scan(cand_k, rects_soa, lines_soa, qs, qe, *, ne):
            return polygon_scan_pallas(
                cand_k, arena.entries, rects_soa, lines_soa, qs, qe,
                ne=ne, dim=dim, interpret=interpret,
            )

        return scan

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------

    @property
    def n_compiles(self) -> int:
        """Distinct (bucketed) shapes traced so far — flat in steady
        state; tests assert it via this introspection hook."""
        return int(
            self._fused._cache_size() + self._route._cache_size()
            + self._fused_routed._cache_size()
            + self._padder._cache_size()
            + self._prepare._cache_size() + self._scan._cache_size()
            + self._count_scan._cache_size()
            + self._collect_scan._cache_size()
            + self._collect_post._cache_size()
            + self._polygon_scan._cache_size()
        )

    def _route_prune(self, us: np.ndarray, rects: np.ndarray):
        """Shared phase 1 for every query class: pad to the batch
        bucket, run the fused route + hierarchical prune, ratchet the
        candidate high-water mark.  Returns ``(Bb, rsoa_dev, forced,
        qs, qe, cand_k)`` with ``cand_k`` already sliced to the K
        bucket."""
        B = len(us)
        fault_point("engine.route_prune", n=B)
        with span("engine.pad_batch", cat="engine"):
            Bb, us_dev, rsoa_dev = self._padder.pad(us, rects)
        with span("engine.route_prune", cat="engine", batch=B):
            forced, qs, qe, cand, cnt, mx = self._prepare(us_dev, rsoa_dev)
            # int(mx) blocks on the device prune, so the span really
            # covers lookup + prune + candidate compaction
            self._kb_hwm = max(
                self._kb_hwm,
                min(_bucket(max(int(mx), 1), 1), self.n_tiles))
        kb = self._kb_hwm
        self.stats["batches"] += 1
        self.stats["queries"] += B
        # tiles_scanned: live candidate tiles (pruning effectiveness);
        # tiles_grid: kernel grid steps incl. bucket padding (actual work
        # — padded steps repeat the last tile, so their DMA is elided)
        self.stats["tiles_scanned"] += int(np.asarray(cnt).sum())
        self.stats["tiles_grid"] += (Bb // TB) * kb
        self.stats["tiles_full_scan"] += (Bb // TB) * self.n_tiles
        return Bb, rsoa_dev, forced, qs, qe, cand[:, :kb]

    def _fused_serve(self, us: np.ndarray, rects: np.ndarray, mode: str,
                     kc=None):
        """One-dispatch serve for reach/count/collect: device pad, then
        the fused route→prune→scan launch at the current capacity
        high-water mark.  ``mx > kcap`` (capacity overflow — the scan
        truncated) ratchets the monotone hwm and re-runs; warmup-only,
        steady state runs exactly once and recompiles nothing.  Returns
        ``(Bb, forced, out)`` — for collect with static ``kc``, ``out``
        is the in-trace ``(top, counts)`` epilogue pair."""
        B = len(us)
        fault_point("engine.route_prune", n=B)
        with span("engine.pad_batch", cat="engine"):
            Bb, us_dev, rsoa_dev = self._padder.pad(us, rects)
        with span("engine.fused", cat="engine", batch=B, mode=mode):
            while True:
                kcap = min(self._kb_hwm, self.n_tiles)
                forced, out, cnt, mx = self._fused(
                    us_dev, rsoa_dev, mode=mode, kcap=kcap, kc=kc)
                # int(mx) blocks on the device, so the span covers the
                # whole fused launch
                mxi = int(mx)
                if mxi <= kcap or kcap >= self.n_tiles:
                    break
                self._kb_hwm = min(_bucket(mxi, 1), self.n_tiles)
                self.stats["fused_reruns"] += 1
        self.stats["batches"] += 1
        self.stats["queries"] += B
        self.stats["tiles_scanned"] += int(np.asarray(cnt).sum())
        self.stats["tiles_grid"] += (Bb // TB) * kcap
        self.stats["tiles_full_scan"] += (Bb // TB) * self.n_tiles
        return Bb, forced, out

    def _obs_batch(self, kind: str, B: int, t0: float) -> None:
        """Gated per-batch registry recording (enabled-only: one
        histogram append + two updates per *batch*, nothing per query)."""
        if not _TRACER.enabled:
            return
        dt_us = (time.perf_counter() - t0) * 1e6
        REGISTRY.histogram("engine.batch_us").record(dt_us)
        REGISTRY.histogram(f"engine.{kind}.query_us").record(dt_us / max(B, 1))
        REGISTRY.counter(f"engine.{kind}.queries").inc(B)
        REGISTRY.gauge("engine.n_compiles").set(self.n_compiles)

    def query_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Batched RangeReach, same contract as ``TwoDReachIndex
        .query_batch`` (and bit-identical to it)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=bool)
        fault_point("engine.query_batch", n=B)
        t0 = time.perf_counter()
        with span("engine.query_batch", cat="engine", n=B):
            if self.path == "fused":
                _, forced, hit = self._fused_serve(us, rects, "reach")
            else:
                _, rsoa_dev, forced, qs, qe, cand_k = self._route_prune(
                    us, rects)
                with span("engine.scan", cat="engine"):
                    hit = self._scan(cand_k, rsoa_dev, qs, qe)
            with span("engine.sync", cat="engine"):
                out = np.asarray(hit).astype(bool) | np.asarray(forced)
        self._obs_batch("reach", B, t0)
        # value point: a kind="corrupt" fault silently flips answers
        # here — the failure the online exactness auditor must catch
        return fault_value("engine.answer", out[:B])

    def query(self, u: int, rect) -> bool:
        return bool(self.query_batch(np.array([u]), np.array([rect]))[0])

    def _with_path(self, path: str, fn, *args):
        prev, self.path = self.path, path
        try:
            return fn(*args)
        finally:
            self.path = prev

    def query_batch_two_phase(self, us, rects) -> np.ndarray:
        """``query_batch`` through the retained two-phase reference path
        (prune → host compaction → descent scan) — the fused path's
        oracle and the ResilientEngine degradation target."""
        return self._with_path("two_phase", self.query_batch, us, rects)

    def count_batch_two_phase(self, us, rects) -> np.ndarray:
        """``count_batch`` through the two-phase reference path."""
        return self._with_path("two_phase", self.count_batch, us, rects)

    def collect_batch_two_phase(self, us, rects, k: int):
        """``collect_batch`` through the two-phase reference path."""
        return self._with_path("two_phase", self.collect_batch,
                               us, rects, k)

    # -- analytics classes (see repro.queries) --------------------------

    def count_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Batched RangeCount: (B,) int64 exact number of reachable
        venues intersecting each rect (bit-identical to the host
        ``repro.queries.range_count_host``)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=np.int64)
        t0 = time.perf_counter()
        with span("engine.count_batch", cat="engine", n=B):
            if self.path == "fused":
                _, forced, counts = self._fused_serve(us, rects, "count")
            else:
                _, rsoa_dev, forced, qs, qe, cand_k = self._route_prune(
                    us, rects)
                with span("engine.scan", cat="engine"):
                    counts = self._count_scan(cand_k, rsoa_dev, qs, qe)
            # forced: an excluded (spatial-sink) query vertex reaches
            # exactly itself — its tree probe counted nothing (empty
            # slice)
            with span("engine.sync", cat="engine"):
                out = (np.asarray(counts).astype(np.int64)
                       + np.asarray(forced).astype(np.int64))
        self._obs_batch("count", B, t0)
        return out[:B]

    def collect_batch(self, us: np.ndarray, rects: np.ndarray, k: int):
        """Batched RangeCollect: the K smallest reachable venue ids in
        each rect + exact totals and overflow flags — see
        ``repro.queries.CollectResult`` (bit-identical to host)."""
        from ..queries.program import CollectResult  # deferred: no cycle

        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        k = int(k)
        if k < 1:
            raise ValueError(f"collect needs k >= 1, got {k}")
        if B == 0:
            return CollectResult(
                ids=np.zeros((0, k), np.int32),
                counts=np.zeros(0, np.int64),
                overflow=np.zeros(0, bool),
            )
        t0 = time.perf_counter()
        with span("engine.collect_batch", cat="engine", n=B):
            if self.path == "fused":
                _, forced, out = self._fused_serve(
                    us, rects, "collect", kc=_bucket(k, 1))
                top, cnt = out
            else:
                _, rsoa_dev, forced, qs, qe, cand_k = self._route_prune(
                    us, rects)
                with span("engine.scan", cat="engine"):
                    mat = self._collect_scan(cand_k, rsoa_dev, qs, qe)
                    top, cnt = self._collect_post(mat, kc=_bucket(k, 1))
        self._obs_batch("collect", B, t0)
        top = np.asarray(top)[:B]
        counts = np.asarray(cnt).astype(np.int64)[:B]
        ids = np.full((B, k), ID_SENTINEL, dtype=np.int32)
        take = min(k, top.shape[1])
        ids[:, :take] = top[:, :take]
        ids[ids == ID_SENTINEL] = -1
        exc = self._excluded_host[us]
        if exc.any():
            hit = np.nonzero(exc & np.asarray(forced)[:B])[0]
            ids[hit, 0] = us[hit]
            counts[hit] = 1
        return CollectResult(ids=ids, counts=counts, overflow=counts > k)

    def knn_batch(self, us: np.ndarray, points: np.ndarray, k: int):
        """Batched KNNReach via the device radius-doubling driver over
        RangeCount/RangeCollect (see ``repro.queries.knn``); results are
        the exact (dist², id)-ordered k nearest reachable venues,
        bit-identical to the host best-first descent."""
        from ..queries.knn import knn_radius_doubling  # deferred: no cycle

        with span("engine.knn_batch", cat="engine", n=len(us), k=k):
            return knn_radius_doubling(self, us, points, k)

    def polygon_batch(self, us: np.ndarray, polygons) -> np.ndarray:
        """Batched convex-polygon RangeReach: the half-plane postfilter
        runs inside the leaf-scan kernel (bbox prune + canonical f32
        region test; bit-identical to host)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=bool)
        if len(polygons) != B:
            raise ValueError(f"{len(polygons)} polygons for {B} queries")
        t0 = time.perf_counter()
        with span("engine.polygon_batch", cat="engine", n=B):
            bboxes = np.stack([polygon_bbox(p) for p in polygons])
            ne = max(len(np.asarray(p).reshape(-1, 2)) for p in polygons)
            neb = _bucket(ne, 4)
            hps = np.stack(
                [convex_halfplanes(p, pad_to=neb) for p in polygons])
            Bb, rsoa_dev, _, qs, qe, cand_k = self._route_prune(us, bboxes)
            # (B, 3, neb) -> (3*neb, Bb); padded batch lanes get inert
            # half-planes (A=B=0, C=+inf) to match their impossible rects
            lines = np.zeros((3 * neb, Bb), dtype=np.float32)
            lines[2 * neb:] = np.inf
            lines[:, :B] = hps.transpose(1, 2, 0).reshape(3 * neb, B)
            with span("engine.scan", cat="engine"):
                hit = self._polygon_scan(cand_k, rsoa_dev,
                                         jnp.asarray(lines),
                                         qs, qe, ne=neb)
            with span("engine.sync", cat="engine"):
                out = np.asarray(hit)[:B] > 0
        self._obs_batch("polygon", B, t0)
        exc = self._excluded_host[us]
        if exc.any():
            for i in np.nonzero(exc)[0]:
                out[i] = bool(points_in_polygon_region(
                    self._coords_host[us[i]][None], bboxes[i], hps[i])[0])
        return out


def _unsupported_msg(index, what: str) -> str:
    name = type(index).__name__
    method = getattr(index, "method", None) or getattr(index, "variant", None)
    via = f" (method {method!r})" if isinstance(method, str) else ""
    return (
        f"no {what} for {name}{via}: device/cluster serving supports the "
        f"2DReach variants only (2dreach, 2dreach-comp, 2dreach-pointer)"
    )


def engine_for(index, interpret: Optional[bool] = None,
               required: bool = False):
    """Memoised ``QueryEngine`` for a built 2DReach index (one upload per
    index instance).

    Supported pairings: any :class:`TwoDReachIndex` variant (``base`` /
    ``comp`` / ``pointer``), from either build backend —
    ``build_2dreach(backend="host")`` uploads its arrays here once;
    ``backend="device"`` indexes are *adopted* zero-copy (the build left
    the serving arrays on device; see ``UPLOAD_COUNTERS``).  For index
    types the device engine does not serve (3DReach, GeoReach, anything
    without a 2D forest), returns ``None`` so callers can fall back to
    the host path — or, with ``required=True``, raises a ``ValueError``
    naming the unsupported index/method (instead of the caller tripping
    an ``AttributeError`` deep inside the engine).  An explicit
    ``interpret`` that disagrees with the memoised engine's mode
    rebuilds rather than silently returning the wrong kernel mode."""
    if not isinstance(index, TwoDReachIndex):
        if required:
            raise ValueError(_unsupported_msg(index, "device QueryEngine"))
        return None
    eng = getattr(index, "_device_engine", None)
    if eng is None or (
        interpret is not None and eng._interpret != bool(interpret)
    ):
        eng = QueryEngine(index, interpret=interpret)
        index._device_engine = eng
    return eng
