"""Chaos suite: the fault-tolerance invariant under injected failures.

The invariant, end to end: **every submitted query resolves to the
exact answer or a typed error — no hangs, no wrong answers** — while
the fault injector raises, delays and stalls at the stack's named
failure points on a deterministic seeded schedule.

The big run (`test_chaos_invariant_bulk_faults`) pushes ≥ 500 injected
faults through the resilient engine and checks exactness on every
single answer; the frontend run layers admission control, queue
deadlines and scheduler-latch faults on top; the compaction tests crash
the swap at every stage boundary and verify the rollback leaves the
dynamic index answering exactly.
"""

from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core import build_index, rangereach_oracle_batch
from repro.core.engine import engine_for
from repro.cluster import Frontend
from repro.dynamic import NEVER, DynamicIndex
from repro.obs.metrics import REGISTRY, Registry
from repro.resilience import (
    BreakerPolicy,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    Overloaded,
    ResilienceError,
    ResilientEngine,
    RetryPolicy,
    fault_point,
    inject,
)
from repro.resilience.faults import INJECTOR
from conftest import given, random_geosocial, random_queries, settings, st

VARIANTS = ("2dreach", "2dreach-comp", "2dreach-pointer")

COMPACTION_POINTS = (
    "dynamic.compaction.build",
    "dynamic.compaction.mid_build",
    "dynamic.compaction.pre_swap",
    "dynamic.compaction.mid_swap",
    "dynamic.compaction.replay",
)


class SimDevice:
    """Device-path stand-in: the exact host answer behind the engine's
    fault point.  The real engines carry the same hook and the same
    exactness contract (bit-identical to the host descent); the sim
    keeps the chaos volume cheap and accelerator-independent."""

    def __init__(self, index):
        self.index = index
        self.calls = 0

    def query_batch(self, us, rects):
        fault_point("engine.query_batch", n=len(us))
        self.calls += 1
        return self.index.query_batch(us, rects)


def _fast_resilient(idx, dev, **kw):
    kw.setdefault("retry",
                  RetryPolicy(max_attempts=2, base_s=1e-6, cap_s=1e-5))
    kw.setdefault("breaker",
                  BreakerPolicy(failure_threshold=2, reset_timeout_s=0.0))
    kw.setdefault("sleep", lambda s: None)
    kw.setdefault("registry", Registry())
    return ResilientEngine(dev, idx, **kw)


@pytest.fixture(scope="module")
def chaos_index():
    rng = np.random.default_rng(42)
    g = random_geosocial(rng, 200, 560)
    idx = build_index(g, "2dreach")
    us, rects = random_queries(rng, g, 400)
    want = idx.query_batch(us, rects)
    # the host index is itself oracle-exact (the invariant's anchor)
    np.testing.assert_array_equal(
        want, rangereach_oracle_batch(g, us, rects))
    return idx, us, rects, want


def test_chaos_invariant_bulk_faults(chaos_index):
    """≥ 500 injected device faults; every answer exact, none lost."""
    idx, us, rects, want = chaos_index
    res = _fast_resilient(idx, SimDevice(idx))
    plan = FaultPlan(
        FaultSpec("engine.query_batch", kind="raise", p=0.6,
                  max_fires=None),
        seed=123)
    injected_before = REGISTRY.counter("faults.injected").value
    wrong = 0
    n_batches = 650                     # ~0.93 fires/batch at p=0.6
    with inject(plan):
        for b in range(n_batches):
            sel = np.arange(b * 4, b * 4 + 4) % len(us)
            got = res.query_batch(us[sel], rects[sel])
            wrong += int((got != want[sel]).sum())
    assert wrong == 0
    assert plan.total_fires >= 500, plan.total_fires
    assert (REGISTRY.counter("faults.injected").value
            >= injected_before + 500)
    # both paths genuinely exercised
    assert res.stats["device_batches"] > 0
    assert res.stats["fallback_batches"] > 0
    assert res.stats["retries"] > 0


def test_chaos_frontend_end_to_end(chaos_index):
    """Frontend + resilient engine under a mixed fault plan: every
    future resolves (bounded wait) to the exact answer or a typed
    error; the scheduler thread survives everything."""
    idx, us, rects, want = chaos_index
    res = _fast_resilient(idx, SimDevice(idx))
    plan = FaultPlan(
        FaultSpec("engine.query_batch", kind="raise", p=0.4,
                  max_fires=None),
        FaultSpec("engine.query_batch", kind="delay", p=0.1,
                  delay_s=2e-4, max_fires=None),
        # scheduler-latch faults: latched onto the batch futures as
        # typed-but-injected errors, never a hang
        FaultSpec("frontend.flush", kind="raise", p=0.05,
                  max_fires=None),
        FaultSpec("frontend.queue_stall", kind="delay", p=0.05,
                  delay_s=2e-4, max_fires=None),
        seed=77)
    shed = served = typed = wrong = 0
    with Frontend(res, max_batch=16, max_delay=5e-4, max_queue=512,
                  metrics=Registry()) as fe:
        with inject(plan):
            futs = []
            for i in range(len(us)):
                try:
                    # a few requests carry deadline budgets — some are
                    # doomed on purpose and must shed or expire typed
                    dl = 0.0 if i % 37 == 0 else (
                        5.0 if i % 5 == 0 else None)
                    futs.append((i, fe.submit(us[i], rects[i],
                                              deadline=dl)))
                except Overloaded:
                    shed += 1
            for i, fut in futs:
                try:
                    got = fut.result(timeout=30)   # bounded: no hangs
                    served += 1
                    wrong += int(got != bool(want[i]))
                except (ResilienceError, InjectedFault):
                    typed += 1
        # faults gone: the surviving scheduler still serves exactly
        assert fe.submit(us[0], rects[0]).result(timeout=30) \
            == bool(want[0])
    assert wrong == 0
    assert served > 0
    assert shed > 0                     # doomed budgets were shed
    assert plan.total_fires > 0
    assert served + typed == len(futs)  # every accepted future resolved


def test_chaos_hang_is_bounded(chaos_index):
    """A hang-kind fault stalls the device call until the plan's
    release — the caller's thread is stuck *inside* the injected hang,
    not lost; release ends it and the answer is still exact."""
    idx, us, rects, want = chaos_index
    res = _fast_resilient(idx, SimDevice(idx))
    plan = FaultPlan(
        FaultSpec("engine.query_batch", kind="hang", hang_s=30.0))
    out = {}
    with inject(plan):
        def call():
            out["got"] = res.query_batch(us[:8], rects[:8])

        t = threading.Thread(target=call, daemon=True)
        t.start()
        t.join(timeout=0.1)
        assert t.is_alive()             # genuinely stalled
        plan.release.set()
        t.join(timeout=30)
        assert not t.is_alive(), "hang must end on release"
    np.testing.assert_array_equal(out["got"], want[:8])


@pytest.mark.parametrize("variant", VARIANTS)
def test_degraded_path_bit_identical_all_variants(variant):
    """The degradation target equals the healthy device path bit for
    bit on every 2DReach variant (PR 2/5 exactness makes the fallback
    free of answer drift)."""
    rng = np.random.default_rng(9)
    g = random_geosocial(rng, 150, 420)
    idx = build_index(g, variant)
    us, rects = random_queries(rng, g, 96)
    dev = engine_for(idx, required=True)
    healthy = ResilientEngine(dev, idx, registry=Registry())
    got_dev = healthy.query_batch(us, rects)
    degraded = ResilientEngine(dev, idx, registry=Registry())
    degraded.trip()
    got_host = degraded.query_batch(us, rects)
    np.testing.assert_array_equal(got_dev, got_host)
    np.testing.assert_array_equal(
        got_host, rangereach_oracle_batch(g, us, rects))
    assert degraded.stats["fallback_batches"] == 1


# ----------------------------------------------------------------------
# crash-safe compaction
# ----------------------------------------------------------------------


def _mutated_dynamic(seed, n=50, m=140, n_ops=25):
    rng = np.random.default_rng(seed)
    g = random_geosocial(rng, n, m)
    dyn = DynamicIndex(g, "2dreach", engine="host", policy=NEVER)
    for _ in range(n_ops):
        dyn.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)))
    us, rects = random_queries(np.random.default_rng(seed + 1),
                               dyn._materialise(), 48)
    want = rangereach_oracle_batch(dyn._materialise(), us, rects)
    return dyn, us, rects, want


def _crash_compaction_at(point, seed):
    dyn, us, rects, want = _mutated_dynamic(seed)
    np.testing.assert_array_equal(dyn.query_batch(us, rects), want)
    with inject(FaultPlan(FaultSpec(point, kind="raise"))):
        with pytest.raises(InjectedFault):
            dyn.compact(background=False)
    # crash at any stage boundary: the pre-swap state is fully restored
    assert dyn.stats["n_compactions"] == 0
    np.testing.assert_array_equal(dyn.query_batch(us, rects), want)
    # and the crashed compaction is retryable
    assert dyn.compact(background=False)
    assert dyn.stats["n_compactions"] == 1
    assert dyn.overlay_size == 0
    np.testing.assert_array_equal(dyn.query_batch(us, rects), want)


@pytest.mark.parametrize("point", COMPACTION_POINTS)
@pytest.mark.parametrize("seed", (3, 17))
def test_compaction_crash_rolls_back(point, seed):
    _crash_compaction_at(point, seed)


@pytest.mark.parametrize("point", COMPACTION_POINTS)
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_compaction_crash_rolls_back_property(point, seed):
    """Property form: any mutation history, any stage boundary — a
    crashed swap never changes an answer."""
    _crash_compaction_at(point, seed)


def test_background_compaction_crash_latches_and_recovers():
    dyn, us, rects, want = _mutated_dynamic(seed=29)
    plan = FaultPlan(
        FaultSpec("dynamic.compaction.mid_swap", kind="raise"))
    with inject(plan):
        assert dyn.compact(background=True)
        with pytest.raises(RuntimeError):
            dyn.join_compaction(timeout=60)
    assert isinstance(dyn.compaction_error, InjectedFault)
    # latched failure suppresses policy-driven retries...
    assert not dyn.maybe_compact()
    # ...but never corrupts answers
    np.testing.assert_array_equal(dyn.query_batch(us, rects), want)
    # explicit retry clears the latch and completes
    assert dyn.compact(background=True)
    dyn.join_compaction(timeout=60)
    assert dyn.compaction_error is None
    assert dyn.stats["n_compactions"] == 1
    np.testing.assert_array_equal(dyn.query_batch(us, rects), want)


def test_compaction_crash_rollback_with_racing_tail():
    """Crash during the op-log replay of mutations that raced the
    build: rollback restores the old overlay (which still carries the
    raced ops), so nothing is lost or double-applied."""
    dyn, us, rects, _ = _mutated_dynamic(seed=31)
    cut_ops = len(dyn._oplog)
    # stage a tail beyond the cut by compacting from a snapshot taken
    # before these mutations: emulate via background build + mutations
    snapshot, cut = dyn._begin_compaction()
    built = dyn._build_static(snapshot)
    rng = np.random.default_rng(5)
    for _ in range(6):                  # race: mutations after the cut
        dyn.add_edge(int(rng.integers(0, dyn.n_base)),
                     int(rng.integers(0, dyn.n_base)))
    want = rangereach_oracle_batch(dyn._materialise(), us, rects)
    np.testing.assert_array_equal(dyn.query_batch(us, rects), want)
    with inject(FaultPlan(
            FaultSpec("dynamic.compaction.replay", kind="raise"))):
        with pytest.raises(InjectedFault):
            dyn._finish_compaction(snapshot, built, cut, 0.0)
    assert len(dyn._oplog) == cut_ops + 6   # op log intact
    np.testing.assert_array_equal(dyn.query_batch(us, rects), want)
    # clean retry replays the tail exactly once
    dyn._finish_compaction(snapshot, built, cut, 0.0)
    np.testing.assert_array_equal(dyn.query_batch(us, rects), want)
    assert INJECTOR.enabled is False
