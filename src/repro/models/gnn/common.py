"""Shared GNN substrate: segment message passing, bases, batch format.

JAX sparse is BCOO-only, so message passing here is built directly on
``jax.ops.segment_sum`` over edge-index arrays — the same scatter/segment
machinery the reachability core uses (see DESIGN.md §Arch-applicability).

Unified single-graph batch format (batched molecules vmap over this):

    pos       (N, 3) float32 | feat (N, F) float32 | species (N,) int32
    edge_src  (E,) int32
    edge_dst  (E,) int32
    edge_mask (E,) bool       padding edges contribute zero
    node_mask (N,) bool
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..nn import Params


def seg_sum(x: jnp.ndarray, idx: jnp.ndarray, n: int) -> jnp.ndarray:
    return jax.ops.segment_sum(x, idx, num_segments=n)


def seg_mean(x: jnp.ndarray, idx: jnp.ndarray, n: int) -> jnp.ndarray:
    s = seg_sum(x, idx, n)
    c = seg_sum(jnp.ones((x.shape[0], 1), x.dtype), idx, n)
    return s / jnp.maximum(c, 1.0)


def seg_max(x: jnp.ndarray, idx: jnp.ndarray, n: int) -> jnp.ndarray:
    return jax.ops.segment_max(x, idx, num_segments=n)


def seg_softmax(scores: jnp.ndarray, idx: jnp.ndarray, n: int) -> jnp.ndarray:
    """Edge-softmax (GAT-style): normalise scores within each dst segment."""
    m = jax.ops.segment_max(scores, idx, num_segments=n)
    e = jnp.exp(scores - m[idx])
    z = seg_sum(e, idx, n)
    return e / jnp.maximum(z[idx], 1e-9)


def edge_vectors(pos: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray):
    """(vec (E,3), dist (E,)) from dst to src convention: r_ji = x_j - x_i
    for edge j->i (message direction src -> dst)."""
    vec = pos[src] - pos[dst]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, -1), 1e-12))
    return vec, dist


def gaussian_rbf(dist: jnp.ndarray, n: int, cutoff: float) -> jnp.ndarray:
    """(E, n) Gaussian radial basis on [0, cutoff] (SchNet-style)."""
    mu = jnp.linspace(0.0, cutoff, n)
    gamma = n / cutoff
    return jnp.exp(-gamma * (dist[:, None] - mu[None, :]) ** 2)


def bessel_rbf(dist: jnp.ndarray, n: int, cutoff: float) -> jnp.ndarray:
    """(E, n) spherical Bessel basis (DimeNet-style) with envelope."""
    d = jnp.maximum(dist, 1e-6)
    freq = jnp.arange(1, n + 1, dtype=jnp.float32) * jnp.pi
    rb = jnp.sqrt(2.0 / cutoff) * jnp.sin(freq[None] * d[:, None] / cutoff) \
        / d[:, None]
    return rb * smooth_cutoff(dist, cutoff)[:, None]


def smooth_cutoff(dist: jnp.ndarray, cutoff: float, p: int = 6) -> jnp.ndarray:
    """DimeNet polynomial envelope u(d) -> 0 smoothly at d = cutoff."""
    x = jnp.clip(dist / cutoff, 0.0, 1.0)
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2.0)
    c = -p * (p + 1) / 2.0
    return 1.0 + a * x**p + b * x ** (p + 1) + c * x ** (p + 2)


def masked_graph_readout(node_out: jnp.ndarray, node_mask) -> jnp.ndarray:
    if node_mask is None:
        return node_out.sum(0)
    return (node_out * node_mask[:, None].astype(node_out.dtype)).sum(0)
