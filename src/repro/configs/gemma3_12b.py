"""gemma3-12b [dense]: 48L d=3840 16H (kv=8, head_dim=256) d_ff=15360
vocab=262144, 5:1 local:global, window 1024, 128k+ context.
[hf:google/gemma-3; unverified]"""
from ..models.lm import LMConfig
from .base import ArchSpec, lm_cells

NAME = "gemma3-12b"


def make_config(reduced: bool = False, dtype: str = "bfloat16") -> LMConfig:
    if reduced:
        return LMConfig(
            name=NAME + "-reduced", n_layers=6, d_model=64, n_heads=4,
            n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, window=16,
            layer_schedule="LLLLLG", embed_scale=True, dtype="float32",
        )
    return LMConfig(
        name=NAME, n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        head_dim=256, d_ff=15360, vocab=262144, window=1024,
        layer_schedule="LLLLLG", embed_scale=True, dtype=dtype,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="lm", make_config=make_config,
        cells=lm_cells(NAME, make_config),
        notes="5:1 SWA keeps long_500k sub-quadratic: only every 6th "
              "layer holds full 500k KV",
    )
