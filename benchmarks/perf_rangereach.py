"""§Perf hillclimb: the paper-technique cell (RangeReach query engine).

Unlike the LM/GNN cells (dry-run roofline terms), the paper's own
workload runs for real on this host, so this hillclimb measures
wall-clock per query across engine variants and structural parameters:

    engine    host wavefront | jit wavefront (capacity c) | pallas leaf
              | device (compile-once QueryEngine, hierarchical descent)
    fanout    R-tree node width (VMEM tile shape analogue)
    capacity  jit wavefront frontier budget

plus the build-side closure: per-level scatter-OR vs the bitset_mm
fixpoint (VPU word loop vs MXU unpack-matmul) at growing component
counts.  Each configuration is correctness-checked against the host
engine before timing.

Outputs: results/perf_rangereach.json (full sweep) and a root-level
BENCH_rangereach.json summary tracking the perf trajectory — leaf tiles
scanned by the hierarchical device engine vs the full leaf scan, and the
steady-state recompile / forest-re-transposition counts (both must stay
zero).  ``--smoke`` runs a seconds-scale subset for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro import obs
from repro.cluster import ShardedEngine
from repro.core import QueryEngine, build_2dreach, query_host, query_jax_wavefront
from repro.data import get_dataset, workload
from repro.kernels.range_query import ops as rq_ops
from repro.kernels.range_query.ops import range_query_forest
from repro.launch.serve import serve_chunked

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "results", "perf_rangereach.json")
BENCH_OUT = os.path.join(ROOT, "BENCH_rangereach.json")

LAT_BATCH = 256   # chunk size for the per-query latency distribution


def _t(fn, repeats=5):
    fn()  # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _lat_pct(call, n, batch=LAT_BATCH) -> Dict[str, float]:
    """p50/p95/p99 per-query latency (us) serving [0, n) in chunks.

    ``call(lo, hi)`` serves that query slice and returns its answers;
    the chunked warm-and-measure mechanics (incl. warming the ragged
    tail's jit shape) live in ``repro.launch.serve.serve_chunked``.

    The chunk size is capped so the pass always yields several batches
    — one amortised sample *per batch* goes into the obs ``Histogram``
    (previously n <= batch collapsed to a single chunk whose one value
    made p50 == p95 == p99) — and small runs take extra passes until
    the distribution holds enough batch samples for a stable p99.
    """
    batch = max(1, min(batch, n // 8 or n))
    n_chunks = -(-n // batch)
    passes = max(1, -(-32 // n_chunks))
    hist = obs.Histogram("bench.lat_us", lo=1e-2, hi=1e9)
    for _ in range(passes):
        _, lats, _ = serve_chunked(call, n, batch)
        # one sample per chunk: lats repeats the chunk's amortised
        # per-query latency across its queries — take the chunk heads
        hist.record_many(np.asarray(lats[::batch]) * 1e6)
    return hist.percentile_dict(prefix="lat_p", suffix="_us")


def _stage_profile(run, prefix, cost_fn=None):
    """One instrumented pass *after* the timed one: per-stage span
    totals (µs) plus the kernel cost model, recorded outside the timed
    loop so span overhead never skews ``us_per_q``."""
    was = obs.enabled()
    obs.enable()
    before = obs.stage_totals(prefix)
    run()
    after = obs.stage_totals(prefix)
    if not was:
        obs.disable()
    stage = {k: round(after.get(k, 0.0) - before.get(k, 0.0), 3)
             for k in after
             if after.get(k, 0.0) > before.get(k, 0.0)}
    return stage, (cost_fn() if cost_fn is not None else None)


def engine_sweep(dataset="gowalla", scale=0.5, n_q=2000,
                 fanouts=(8, 16, 32, 64), capacities=(32, 64, 128, 256),
                 repeats=5, n_shards=None) -> List[Dict]:
    g = get_dataset(dataset, scale=scale)
    us, rects = workload(g, n_q, extent_ratio=0.05, seed=5)
    rows = []
    for fanout in fanouts:
        idx = build_2dreach(g, variant="comp", fanout=fanout)
        tid = idx.lookup_tree(us)
        ref = query_host(idx.forest, tid, rects)
        full = idx.query_batch(us, rects)
        # host engine
        dt = _t(lambda: query_host(idx.forest, tid, rects), repeats=repeats)
        rows.append(dict(engine="host", fanout=fanout, capacity=None,
                         us_per_q=dt / n_q * 1e6,
                         depth=idx.forest.depth,
                         **_lat_pct(lambda lo, hi: query_host(
                             idx.forest, tid[lo:hi], rects[lo:hi]), n_q)))
        # jit wavefront at several capacities
        for cap in capacities:
            got, ovf = query_jax_wavefront(idx.forest, tid, rects,
                                           capacity=cap)
            valid = ~np.asarray(ovf)
            assert (np.asarray(got)[valid] == ref[valid]).all()
            ovf_frac = float(np.asarray(ovf).mean())
            dt = _t(lambda: query_jax_wavefront(
                idx.forest, tid, rects, capacity=cap), repeats=repeats)
            rows.append(dict(engine="wavefront", fanout=fanout,
                             capacity=cap, us_per_q=dt / n_q * 1e6,
                             overflow_frac=ovf_frac,
                             depth=idx.forest.depth,
                             **_lat_pct(lambda lo, hi: query_jax_wavefront(
                                 idx.forest, tid[lo:hi], rects[lo:hi],
                                 capacity=cap)[0], n_q)))
        # pallas leaf scan (interpret on CPU — structural comparison)
        got = range_query_forest(idx.forest, tid, rects)
        assert (got == ref).all()
        dt = _t(lambda: range_query_forest(idx.forest, tid, rects),
                repeats=3)
        rows.append(dict(engine="pallas_leafscan", fanout=fanout,
                         capacity=None, us_per_q=dt / n_q * 1e6,
                         depth=idx.forest.depth,
                         **_lat_pct(lambda lo, hi: range_query_forest(
                             idx.forest, tid[lo:hi], rects[lo:hi]), n_q)))
        # device engine: compile-once hierarchical descent
        eng = QueryEngine(idx)
        got = eng.query_batch(us, rects)
        assert (got == full).all(), "device engine disagrees with host"
        # steady-state gates: repeat queries, then assert no new traces
        # and no new host-side forest transpositions
        compiles0 = eng.n_compiles
        soa0 = rq_ops.SOA_BUILDS
        tiles0 = eng.stats["tiles_scanned"]
        grid0 = eng.stats["tiles_grid"]
        full0 = eng.stats["tiles_full_scan"]
        dt = _t(lambda: eng.query_batch(us, rects), repeats=repeats)
        recompiles = eng.n_compiles - compiles0
        retranspositions = rq_ops.SOA_BUILDS - soa0
        batches = eng.stats["batches"] - 1  # minus pre-gate warm batch
        tiles_pb = (eng.stats["tiles_scanned"] - tiles0) / max(batches, 1)
        grid_pb = (eng.stats["tiles_grid"] - grid0) / max(batches, 1)
        full_pb = (eng.stats["tiles_full_scan"] - full0) / max(batches, 1)
        stage_us, cost = _stage_profile(
            lambda: eng.query_batch(us, rects), "engine.",
            lambda: obs.engine_cost_model(eng))
        # retained two-phase path: same answers, separate launches —
        # timed and span-attributed alongside the fused trace so the
        # artifact carries the fusion win per stage
        got2 = eng.query_batch_two_phase(us, rects)
        assert (got2 == full).all(), "two-phase disagrees with host"
        dt2 = _t(lambda: eng.query_batch_two_phase(us, rects),
                 repeats=repeats)
        stage2_us, _ = _stage_profile(
            lambda: eng.query_batch_two_phase(us, rects), "engine.")
        rows.append(dict(
            engine="device", fanout=fanout, capacity=None,
            us_per_q=dt / n_q * 1e6, depth=idx.forest.depth,
            n_leaf_tiles=eng.n_tiles,
            stage_us=stage_us, cost_model=cost,
            two_phase_us_per_q=dt2 / n_q * 1e6,
            two_phase_stage_us=stage2_us,
            tiles_scanned_per_batch=tiles_pb,
            tiles_grid_per_batch=grid_pb,
            tiles_full_scan_per_batch=full_pb,
            steady_state_recompiles=recompiles,
            steady_state_retranspositions=retranspositions,
            **_lat_pct(lambda lo, hi: eng.query_batch(
                us[lo:hi], rects[lo:hi]), n_q),
        ))
        # cluster engine: sharded multi-device serving.  The default
        # (n_shards=None) runs shards == devices — the configuration the
        # cluster<=2x-device ratio gate speaks about; stacked-shard
        # emulation (more shards than devices) stays covered by the
        # cluster tests
        ceng = ShardedEngine(idx, n_shards=n_shards)
        got = ceng.query_batch(us, rects)
        assert (got == full).all(), "cluster engine disagrees with host"
        pct = _lat_pct(lambda lo, hi: ceng.query_batch(
            us[lo:hi], rects[lo:hi]), n_q)
        got2 = ceng.query_batch_two_phase(us, rects)   # warm both paths
        assert (got2 == full).all(), "cluster two-phase disagrees"
        compiles0 = ceng.n_compiles
        soa0 = rq_ops.SOA_BUILDS
        dt = _t(lambda: ceng.query_batch(us, rects), repeats=repeats)
        cstage_us, ccost = _stage_profile(
            lambda: ceng.query_batch(us, rects), "cluster.",
            lambda: obs.engine_cost_model(ceng))
        cdt2 = _t(lambda: ceng.query_batch_two_phase(us, rects),
                  repeats=repeats)
        cstage2_us, _ = _stage_profile(
            lambda: ceng.query_batch_two_phase(us, rects), "cluster.")
        rows.append(dict(
            engine="cluster", fanout=fanout, capacity=None,
            us_per_q=dt / n_q * 1e6, depth=idx.forest.depth,
            n_shards=ceng.n_shards,
            stage_us=cstage_us, cost_model=ccost,
            two_phase_us_per_q=cdt2 / n_q * 1e6,
            two_phase_stage_us=cstage2_us,
            n_devices=int(ceng.mesh.shape["data"]),
            shard_balance=ceng.partition.balance(),
            shard_queries=ceng.shard_queries.tolist(),
            steady_state_recompiles=ceng.n_compiles - compiles0,
            steady_state_retranspositions=rq_ops.SOA_BUILDS - soa0,
            **pct,
        ))
    return rows


def degraded_arm(dataset="gowalla", scale=0.5, n_q=2000, fanout=16,
                 repeats=5) -> Dict:
    """Host-fallback latency under forced degradation (``--degraded``).

    Trips the resilient wrapper's breaker so every query takes the
    exact host descent, the path a dead device degrades to — recording
    what the SLO costs when the accelerator is gone.  Answers are
    asserted bit-identical to the healthy device path first."""
    from repro.resilience import BreakerPolicy, ResilientEngine

    g = get_dataset(dataset, scale=scale)
    us, rects = workload(g, n_q, extent_ratio=0.05, seed=5)
    idx = build_2dreach(g, variant="comp", fanout=fanout)
    res = ResilientEngine(
        QueryEngine(idx), idx,
        breaker=BreakerPolicy(reset_timeout_s=float("inf")))
    healthy = res.query_batch(us, rects)
    dt_dev = _t(lambda: res.query_batch(us, rects), repeats=repeats)
    res.trip()                        # breaker never half-opens again
    got = res.query_batch(us, rects)
    exact = bool((got == healthy).all())
    assert exact, "degraded answers drifted from the device path"
    dt_host = _t(lambda: res.query_batch(us, rects), repeats=repeats)
    pct = _lat_pct(lambda lo, hi: res.query_batch(us[lo:hi],
                                                  rects[lo:hi]), n_q)
    deg_hist = res._h_degraded.snapshot()
    return dict(
        fanout=fanout, n_q=n_q, exact=exact,
        healthy_us_per_q=dt_dev / n_q * 1e6,
        degraded_us_per_q=dt_host / n_q * 1e6,
        degradation_x=dt_host / dt_dev if dt_dev else None,
        fallback_batches=int(res.stats["fallback_batches"]),
        fallback_queries=int(res.stats["fallback_queries"]),
        degraded_hist_count=int(deg_hist["count"]),
        **pct)


def closure_sweep(scales=(0.1, 0.25, 0.5)) -> List[Dict]:
    """Build-side: per-level scatter-OR vs bitset-matmul fixpoint."""
    from repro.core import condense, scc_np
    from repro.core.reachability import closure_np, pack_rows
    from repro.kernels.bitset_mm.ops import closure_fixpoint

    rows = []
    for scale in scales:
        g = get_dataset("yelp", scale=scale)
        labels = scc_np(g.n_nodes, g.edges)
        cond = condense(g.n_nodes, g.edges, labels)
        t0 = time.perf_counter()
        clo = closure_np(cond, g.n_nodes, g.spatial_ids)
        t_np = time.perf_counter() - t0
        d, p = cond.n_comps, clo.p
        rows.append(dict(method="scatter_or_levels", scale=scale,
                         n_comps=d, n_spatial=p, seconds=t_np))
        if d <= 12000:
            # dense closure paths only feasible at small d
            own = np.zeros((d, p), dtype=bool)
            own[np.repeat(np.arange(d), np.diff(clo.own_indptr)),
                clo.own_cols] = True
            A = np.zeros((d, d), dtype=bool)
            if cond.dag_edges.size:
                A[cond.dag_edges[:, 0], cond.dag_edges[:, 1]] = True
            ob, ab = pack_rows(own), pack_rows(A)
            t0 = time.perf_counter()
            closure_fixpoint(ob, ab, n_iters=cond.n_levels + 1,
                             use_mxu=True)
            rows.append(dict(method="bitset_mm_mxu", scale=scale,
                             n_comps=d, n_spatial=p,
                             seconds=time.perf_counter() - t0))
    return rows


def bench_summary(engine_rows: List[Dict]) -> Dict:
    """Root-level perf-trajectory datapoint (BENCH_rangereach.json)."""
    device = [r for r in engine_rows if r["engine"] == "device"]
    cluster = [r for r in engine_rows if r["engine"] == "cluster"]
    best = {}
    pct = {}
    for name in ("host", "wavefront", "pallas_leafscan", "device",
                 "cluster"):
        cands = [r for r in engine_rows if r["engine"] == name]
        if cands:
            best[name] = min(r["us_per_q"] for r in cands)
            winner = min(cands, key=lambda r: r["us_per_q"])
            if "lat_p50_us" in winner:
                pct[name] = {p: winner[f"lat_{p}_us"]
                             for p in ("p50", "p95", "p99")}
    scanned = sum(r["tiles_scanned_per_batch"] for r in device)
    grid = sum(r["tiles_grid_per_batch"] for r in device)
    full = sum(r["tiles_full_scan_per_batch"] for r in device)

    def _winner_stages(rows):
        if not rows:
            return None
        w = min(rows, key=lambda r: r["us_per_q"])
        out = {"stage_us": w.get("stage_us"),
               "cost_model": w.get("cost_model")}
        if w.get("two_phase_us_per_q") is not None:
            # fused-vs-two-phase attribution: the same engine serving
            # the same workload through the retained two-launch path
            out["fused_us_per_q"] = w["us_per_q"]
            out["two_phase_us_per_q"] = w["two_phase_us_per_q"]
            out["two_phase_stage_us"] = w.get("two_phase_stage_us")
            out["fusion_speedup_x"] = (
                w["two_phase_us_per_q"] / w["us_per_q"]
                if w["us_per_q"] else None)
        return out

    return {
        "schema_version": 2,
        "unit": "us_per_query (best over structural params)",
        "engines": best,
        # per-stage host-span attribution + kernel cost model of the
        # best device / cluster configurations (additive in v2)
        "per_stage": {
            "device": _winner_stages(device),
            "cluster": _winner_stages(cluster),
        },
        "latency_percentiles_us": pct,
        "cluster_engine": {
            "n_shards": cluster[0]["n_shards"] if cluster else None,
            "n_devices": cluster[0]["n_devices"] if cluster else None,
            "shard_balance": max(
                (r["shard_balance"] for r in cluster), default=None),
            "steady_state_recompiles": int(sum(
                r["steady_state_recompiles"] for r in cluster)),
            "steady_state_retranspositions": int(sum(
                r["steady_state_retranspositions"] for r in cluster)),
        },
        "hierarchical_device_engine": {
            "leaf_tiles_scanned_per_batch": scanned,
            "grid_steps_per_batch_incl_bucket_padding": grid,
            "leaf_tiles_full_scan_per_batch": full,
            "scan_fraction": scanned / full if full else None,
            "strictly_fewer_than_full_scan": bool(scanned < full),
            "steady_state_recompiles": int(sum(
                r["steady_state_recompiles"] for r in device)),
            "steady_state_retranspositions": int(sum(
                r["steady_state_retranspositions"] for r in device)),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI: one fanout/"
                         "capacity, small dataset, no closure sweep")
    ap.add_argument("--degraded", action="store_true",
                    help="also time the exact host-fallback path with "
                         "the breaker tripped (additive 'degraded' "
                         "field in BENCH_rangereach.json)")
    args = ap.parse_args()

    if args.smoke:
        engines = engine_sweep(dataset="yelp", scale=0.1, n_q=256,
                               fanouts=(16,), capacities=(64,), repeats=2)
        closure = closure_sweep(scales=(0.1,))
    else:
        engines = engine_sweep()
        closure = closure_sweep()
    degraded = None
    if args.degraded:
        degraded = (degraded_arm(dataset="yelp", scale=0.1, n_q=256,
                                 repeats=2)
                    if args.smoke else degraded_arm())
    out = {"engine_sweep": engines, "closure": closure}
    if degraded is not None:
        out["degraded"] = degraded
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(out, f, indent=1)
    summary = bench_summary(engines)
    if degraded is not None:
        # additive field: schema_version stays 2, consumers of the
        # existing keys are unaffected
        summary["degraded"] = degraded
    with open(BENCH_OUT, "w") as f:
        json.dump(summary, f, indent=1)
    for r in engines:
        print(r)
    for r in closure:
        print(r)
    print(json.dumps(summary, indent=1))
    dev = summary["hierarchical_device_engine"]
    assert dev["strictly_fewer_than_full_scan"], \
        "hierarchical engine failed to prune any leaf tiles"
    assert dev["steady_state_recompiles"] == 0, "steady-state recompile"
    assert dev["steady_state_retranspositions"] == 0, \
        "steady-state host-side forest re-transposition"
    clu = summary["cluster_engine"]
    assert clu["steady_state_recompiles"] == 0, \
        "cluster steady-state recompile"
    assert clu["steady_state_retranspositions"] == 0, \
        "cluster steady-state host-side forest re-transposition"
    assert all("p99" in v for v in
               summary["latency_percentiles_us"].values()), \
        "latency percentiles missing from the bench summary"
    if degraded is not None:
        assert degraded["exact"], "degraded arm must stay bit-identical"
        assert degraded["fallback_queries"] > 0, \
            "degraded arm never reached the host fallback"


if __name__ == "__main__":
    main()
