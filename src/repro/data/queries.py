"""RangeReach query-workload generators — the paper's three parameters.

Section 5.1: per parameter value, 1000 queries; the default values of the
other parameters are subsumed.

* region extent ratio   — query region area as a percentage of the global
                          spatial extent (1/2/5/10/20 %, default 5%).
* vertex degree         — out-degree bucket of the query vertex
                          ([1-49] ... [200-], default [100-149]); the
                          generator relaxes a bucket to the nearest
                          non-empty one on scaled graphs and reports it.
* spatial selectivity   — number of spatial vertices inside the region as
                          a fraction of graph nodes (0.001..1 %); regions
                          are grown around a sampled venue until the count
                          matches (Chebyshev-radius quantile).
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..core.graph import GeosocialGraph

REGION_EXTENT_VALUES = (0.01, 0.02, 0.05, 0.10, 0.20)
REGION_EXTENT_DEFAULT = 0.05
DEGREE_BUCKETS = ((1, 49), (50, 99), (100, 149), (150, 199), (200, 10**9))
DEGREE_DEFAULT = (100, 149)
SELECTIVITY_VALUES = (0.00001, 0.0001, 0.001, 0.01)


def sample_vertices_by_degree(
    g: GeosocialGraph,
    bucket: Tuple[int, int],
    n: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Query vertices whose out-degree falls in [lo, hi]; on scaled graphs
    an empty bucket falls back to the closest available degrees."""
    deg = g.out_degree()
    lo, hi = bucket
    cand = np.nonzero((deg >= lo) & (deg <= hi))[0]
    if len(cand) == 0:
        # nearest-degree fallback: take the n vertices closest to the
        # bucket midpoint (keeps the sweep meaningful at small scale)
        mid = lo if hi >= 10**9 else (lo + hi) / 2
        order = np.argsort(np.abs(deg - mid), kind="stable")
        cand = order[: max(n, 100)]
    return rng.choice(cand, size=n, replace=len(cand) < n)


def region_for_extent(
    g: GeosocialGraph, ratio: float, n: int, rng: np.random.Generator
) -> np.ndarray:
    """(n, 4) square regions with area = ratio * extent area, centred at
    uniform points of the extent (paper's region-extent sweep)."""
    ext = g.spatial_extent()
    w = ext[2] - ext[0]
    h = ext[3] - ext[1]
    side_x = w * np.sqrt(ratio)
    side_y = h * np.sqrt(ratio)
    cx = rng.random(n) * w + ext[0]
    cy = rng.random(n) * h + ext[1]
    return np.stack(
        [cx - side_x / 2, cy - side_y / 2, cx + side_x / 2, cy + side_y / 2],
        axis=1,
    ).astype(np.float32)


def region_for_selectivity(
    g: GeosocialGraph, selectivity: float, n: int, rng: np.random.Generator
) -> np.ndarray:
    """(n, 4) square regions containing ~selectivity * n_nodes venues,
    grown around sampled venues by Chebyshev-radius quantile."""
    pts = g.coords[g.spatial_mask]
    k = max(1, int(round(selectivity * g.n_nodes)))
    k = min(k, len(pts))
    centers = pts[rng.integers(0, len(pts), size=n)]
    rects = np.empty((n, 4), dtype=np.float32)
    for i, c in enumerate(centers):
        cheb = np.maximum(np.abs(pts[:, 0] - c[0]), np.abs(pts[:, 1] - c[1]))
        r = np.partition(cheb, k - 1)[k - 1] + 1e-6
        rects[i] = (c[0] - r, c[1] - r, c[0] + r, c[1] + r)
    return rects


KNN_DEFAULT_K = 10
POLYGON_EDGE_VALUES = (3, 4, 6, 8, 12)
POLYGON_EDGES_DEFAULT = 6


def knn_workload(
    g: GeosocialGraph,
    n_queries: int = 1000,
    degree_bucket: Tuple[int, int] = DEGREE_DEFAULT,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """(us, points) for the KNNReach class: query vertices by the
    paper's degree-bucket methodology, focus points uniform over the
    spatial extent."""
    rng = np.random.default_rng(seed)
    us = sample_vertices_by_degree(g, degree_bucket, n_queries, rng)
    ext = g.spatial_extent()
    w = max(float(ext[2] - ext[0]), 1e-3)
    h = max(float(ext[3] - ext[1]), 1e-3)
    points = np.stack(
        [rng.random(n_queries) * w + ext[0],
         rng.random(n_queries) * h + ext[1]],
        axis=1,
    ).astype(np.float32)
    return us.astype(np.int64), points


def polygon_workload(
    g: GeosocialGraph,
    n_queries: int = 1000,
    n_edges: int = POLYGON_EDGES_DEFAULT,
    extent_ratio: float = REGION_EXTENT_DEFAULT,
    degree_bucket: Tuple[int, int] = DEGREE_DEFAULT,
    seed: int = 0,
) -> Tuple[np.ndarray, Tuple[np.ndarray, ...]]:
    """(us, polygons) for the convex-polygon class: per query an
    ``n_edges``-gon inscribed in an ellipse whose area tracks the
    region-extent sweep — vertices at sorted random angles, which is
    convex by construction."""
    rng = np.random.default_rng(seed)
    us = sample_vertices_by_degree(g, degree_bucket, n_queries, rng)
    ext = g.spatial_extent()
    w = max(float(ext[2] - ext[0]), 1e-3)
    h = max(float(ext[3] - ext[1]), 1e-3)
    rx = w * np.sqrt(extent_ratio) / 2
    ry = h * np.sqrt(extent_ratio) / 2
    polys = []
    for _ in range(n_queries):
        cx = rng.random() * w + ext[0]
        cy = rng.random() * h + ext[1]
        ang = np.sort(rng.random(n_edges) * 2 * np.pi)
        # nudge coincident angles apart so the polygon is proper
        ang = ang + np.arange(n_edges) * 1e-6
        polys.append(np.stack(
            [cx + rx * np.cos(ang), cy + ry * np.sin(ang)], axis=1
        ).astype(np.float32))
    return us.astype(np.int64), tuple(polys)


ZIPF_DEFAULT_S = 1.2


def zipf_workload(
    g: GeosocialGraph,
    n_queries: int = 1000,
    s: float = ZIPF_DEFAULT_S,
    extent_ratio: float = REGION_EXTENT_DEFAULT,
    seed: int = 0,
    max_ranks: int = 100_000,
) -> Tuple[np.ndarray, np.ndarray]:
    """(us, rects) with Zipf(s)-skewed query vertices — the workload the
    heavy-hitter analytics and hot-shard placement report are for.

    Vertices are ranked by out-degree descending (popular users are
    popular query subjects — the LBSN assumption) and rank ``r`` is
    drawn with probability proportional to ``r^-s``; at the default
    ``s=1.2`` the top handful of vertices dominate the stream, so an
    exact recount of the served log has a non-trivial heavy-hitter set
    to check the Space-Saving sketch against.  Regions follow the
    paper's region-extent methodology (uniform centres).
    """
    if s <= 0:
        raise ValueError(f"zipf exponent must be > 0, got {s}")
    rng = np.random.default_rng(seed)
    deg = g.out_degree()
    n_ranks = min(g.n_nodes, int(max_ranks))
    # stable sort so equal-degree vertices rank deterministically
    ranked = np.argsort(-deg, kind="stable")[:n_ranks]
    p = np.arange(1, n_ranks + 1, dtype=np.float64) ** -float(s)
    p /= p.sum()
    us = ranked[rng.choice(n_ranks, size=n_queries, p=p)]
    rects = region_for_extent(g, extent_ratio, n_queries, rng)
    return us.astype(np.int64), rects


STREAM_OP_KINDS = ("query", "add_edge", "add_vertex", "add_spatial")


def streaming_workload(
    g: GeosocialGraph,
    n_steps: int = 1000,
    seed: int = 0,
    p_query: float = 0.5,
    p_edge: float = 0.3,
    p_vertex: float = 0.1,
    p_spatial: float = 0.1,
    extent_ratio: float = REGION_EXTENT_DEFAULT,
    new_spatial_frac: float = 0.5,
):
    """Generate a serving-node stream interleaving updates and queries.

    Yields one op tuple per step, against the *mutating* graph (the
    generator tracks vertices it created so updates and queries target
    them too):

    * ``("query", u, rect)``          — RangeReach probe; ``rect`` is a
      (4,) float32 region with area ``extent_ratio`` of the extent.
    * ``("add_edge", s, t)``          — new social/check-in edge.
    * ``("add_vertex", coords|None)`` — new user (None) or venue (x, y).
    * ``("add_spatial", v, (x, y))``  — check-in: existing non-spatial
      vertex v acquires a coordinate.

    The op mix is ``p_query/p_edge/p_vertex/p_spatial`` (normalised).
    ``add_spatial`` falls back to ``add_edge`` once every vertex is
    spatial.  Feed the ops to ``repro.dynamic.DynamicIndex`` (or any
    consumer mirroring the mutation semantics).
    """
    rng = np.random.default_rng(seed)
    probs = np.array([p_query, p_edge, p_vertex, p_spatial], dtype=np.float64)
    probs = probs / probs.sum()
    ext = g.spatial_extent()
    w = max(float(ext[2] - ext[0]), 1e-3)
    h = max(float(ext[3] - ext[1]), 1e-3)

    n = g.n_nodes
    nonspatial = list(np.nonzero(~g.spatial_mask)[0])

    def rand_xy():
        return (float(ext[0] + rng.random() * w),
                float(ext[1] + rng.random() * h))

    for _ in range(n_steps):
        kind = STREAM_OP_KINDS[int(rng.choice(4, p=probs))]
        if kind == "add_spatial" and not nonspatial:
            kind = "add_edge"
        if kind == "query":
            u = int(rng.integers(0, n))
            rect = region_for_extent(g, extent_ratio, 1, rng)[0]
            yield ("query", u, rect)
        elif kind == "add_edge":
            s = int(rng.integers(0, n))
            t = int(rng.integers(0, n))
            yield ("add_edge", s, t)
        elif kind == "add_vertex":
            if rng.random() < new_spatial_frac:
                yield ("add_vertex", rand_xy())
            else:
                nonspatial.append(n)
                yield ("add_vertex", None)
            n += 1
        else:  # add_spatial
            i = int(rng.integers(0, len(nonspatial)))
            v = int(nonspatial.pop(i))
            yield ("add_spatial", v, rand_xy())


def apply_stream_op(index, op):
    """Apply one ``streaming_workload`` op to a DynamicIndex-compatible
    consumer; returns the (u, rect) pair for query ops, else None."""
    if op[0] == "query":
        return op[1], op[2]
    if op[0] == "add_edge":
        index.add_edge(op[1], op[2])
    elif op[0] == "add_vertex":
        index.add_vertex(op[1])
    else:
        index.add_spatial(op[1], op[2])
    return None


def workload(
    g: GeosocialGraph,
    n_queries: int = 1000,
    extent_ratio: Optional[float] = REGION_EXTENT_DEFAULT,
    degree_bucket: Tuple[int, int] = DEGREE_DEFAULT,
    selectivity: Optional[float] = None,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """(us, rects) per the paper's methodology: selectivity overrides the
    extent ratio when given."""
    rng = np.random.default_rng(seed)
    us = sample_vertices_by_degree(g, degree_bucket, n_queries, rng)
    if selectivity is not None:
        rects = region_for_selectivity(g, selectivity, n_queries, rng)
    else:
        rects = region_for_extent(g, extent_ratio, n_queries, rng)
    return us.astype(np.int64), rects
