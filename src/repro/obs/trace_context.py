"""Per-request trace context: the causal key of stage-3 observability.

A :class:`TraceContext` is minted once per request at ``Frontend.submit``
(trace id, admission timestamp, deadline budget, query class, vertex /
rect class) and travels with the request through the whole serving
stack.  Layers do not pass it explicitly — the frontend scheduler
**activates** the batch's contexts for the dynamic extent of the engine
call (:func:`scope`), and every instrumented site reads the ambient
batch through :func:`current` / :func:`current_ids`:

* the span tracer attaches ``trace_ids`` to every span recorded while a
  scope is active, so the padder, the fused megakernel batch, the
  ``ShardedEngine`` fan-out and the ``DynamicIndex`` base/overlay probes
  all carry the ids of the requests they served;
* ``ResilientEngine`` attributes every retry / breaker refusal /
  degradation decision to the specific trace ids it affected
  (``last_report``);
* the structured query log writes one ``trace_id`` + ``attempt`` per
  record (schema v3), and the latency histograms keep (trace id, value)
  exemplars per bucket.

The scope is **thread-local** (the frontend serves a batch on one
scheduler thread; background threads — compaction builders, the
exactness auditor's shadow replays — deliberately run scope-free so
their spans never masquerade as request work).  Minting and scope
activation are a few hundred nanoseconds per *request* / per *batch*
and are always-on; everything per-span stays behind the tracer's
enabled gate, so the disabled hot path is unchanged (gated by
``benchmarks/obs_overhead.py``).
"""

from __future__ import annotations

import itertools
import threading
from typing import List, Optional, Sequence, Tuple

# process-global monotonically increasing ids; itertools.count.__next__
# is atomic under CPython, so minting takes no lock
_NEXT_ID = itertools.count(1)


class TraceContext:
    """One request's identity and admission-time facts.

    ``attempt`` is mutable: the resilient engine bumps it once per
    device attempt that included this request, so by completion it
    reads "how many device calls this answer cost".
    """

    __slots__ = ("trace_id", "t_admit", "deadline", "query_class", "u",
                 "vertex_class", "rect_bucket", "attempt")

    def __init__(self, trace_id: int, t_admit: float = 0.0,
                 deadline: Optional[float] = None,
                 query_class: str = "reach", u: int = -1,
                 vertex_class: str = "unknown", rect_bucket: int = -64,
                 attempt: int = 0):
        self.trace_id = int(trace_id)
        self.t_admit = float(t_admit)
        self.deadline = None if deadline is None else float(deadline)
        self.query_class = query_class
        self.u = int(u)
        self.vertex_class = vertex_class
        self.rect_bucket = int(rect_bucket)
        self.attempt = int(attempt)

    def to_dict(self) -> dict:
        return {
            "trace_id": self.trace_id, "t_admit": self.t_admit,
            "deadline": self.deadline, "query_class": self.query_class,
            "u": self.u, "vertex_class": self.vertex_class,
            "rect_bucket": self.rect_bucket, "attempt": self.attempt,
        }

    def __repr__(self) -> str:
        return (f"TraceContext(id={self.trace_id}, u={self.u}, "
                f"class={self.query_class!r}, attempt={self.attempt})")


def mint(u: int = -1, query_class: str = "reach",
         t_admit: float = 0.0, deadline: Optional[float] = None,
         **kw) -> TraceContext:
    """A fresh context with the next process-global trace id."""
    return TraceContext(next(_NEXT_ID), t_admit=t_admit,
                        deadline=deadline, query_class=query_class,
                        u=u, **kw)


#: the shared no-identity context (trace id -1).  The frontend hands it
#: to requests admitted while tracing is disabled, so the disabled hot
#: path pays one enabled-check per submit instead of a mint — the same
#: gate discipline every per-span cost follows.  Never mutate it.
NULL = TraceContext(-1)


_TLS = threading.local()


class scope:
    """Activate a batch of contexts for the dynamic extent of a with
    block (re-entrant: scopes nest as a stack per thread)::

        with trace_context.scope(ctxs):
            engine.query_batch(us, rects)   # spans carry the ids

    The ids tuple is precomputed once on entry so per-span attachment
    is a thread-local read plus one reference, not a list build.
    """

    __slots__ = ("_ctxs", "_ids")

    def __init__(self, ctxs: Sequence[TraceContext]):
        self._ctxs = tuple(ctxs)
        self._ids = [c.trace_id for c in self._ctxs]

    def __enter__(self) -> "scope":
        stack = getattr(_TLS, "stack", None)
        if stack is None:
            stack = _TLS.stack = []
        stack.append(self)
        return self

    def __exit__(self, *exc) -> bool:
        _TLS.stack.pop()
        return False


def current() -> Optional[Tuple[TraceContext, ...]]:
    """The innermost active batch of contexts on this thread, or None."""
    stack = getattr(_TLS, "stack", None)
    if not stack:
        return None
    return stack[-1]._ctxs


def current_ids() -> Optional[List[int]]:
    """The innermost active batch's trace ids (shared list — treat as
    read-only), or None when no scope is active on this thread."""
    stack = getattr(_TLS, "stack", None)
    if not stack:
        return None
    return stack[-1]._ids
