"""Bench-regression sentinel: BENCH_*.json runs vs a committed history.

The three perf benches (``perf_rangereach``, ``perf_build``,
``perf_queries``) each emit a structured ``BENCH_*.json``, but until
now nothing *read* the trajectory — a PR could double the device
engine's µs/query and CI would stay green as long as the exactness
gates held.  This tool closes the loop:

1. **extract** a flat ``{metric path: value}`` view of each BENCH file
   (latency-like metrics only — lower is better for everything
   tracked here);
2. **compare** the current run against a noise-aware baseline: the
   median of the last ``--baseline-n`` history entries for that metric
   (median, not mean, so one noisy CI run cannot poison the baseline),
   with a configurable relative tolerance — global ``--tol`` plus
   per-metric ``--metric-tol name=frac`` overrides;
3. **gate** the history-free structural ratios (:data:`RATIO_GATES`):
   per query class the fused device engine must not be slower than the
   host descent (``device_us_per_q <= host_us_per_q``) and the cluster
   emulation must stay within 2x the single device — same-run ratios,
   so they hold on any machine speed;
4. **append** the run to ``results/bench_history.jsonl`` (one JSON
   object per line: timestamp, bench, label, metrics) so the next run
   sees it;
5. print a per-metric verdict table and **exit nonzero** when any
   metric regressed past tolerance or any ratio gate broke.

Usage::

    python benchmarks/perf_rangereach.py --smoke
    python benchmarks/regress.py                      # check + append all
    python benchmarks/regress.py --tol 1.0 --label ci # cross-machine CI
    python benchmarks/regress.py --no-append --bench BENCH_build.json

Tolerance guidance: local same-machine history supports a tight
``--tol 0.25``; the CI gate runs ``--tol 1.0`` because the committed
seed history and the CI runner are different machines — it catches
algorithmic regressions (2x+), not scheduler noise.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HISTORY = os.path.join(ROOT, "results", "bench_history.jsonl")
BENCHES = ("BENCH_rangereach.json", "BENCH_build.json",
           "BENCH_queries.json")

SCHEMA_VERSION = 1

#: verdicts, in severity order
OK, IMPROVED, NEW, REGRESSED = "ok", "improved", "new", "REGRESSED"


# ---------------------------------------------------------------- extract

def _extract_rangereach(doc: dict) -> Dict[str, float]:
    out = {f"engines.{k}": float(v)
           for k, v in doc.get("engines", {}).items()}
    for eng, pct in doc.get("latency_percentiles_us", {}).items():
        if "p99" in pct:
            out[f"latency.{eng}.p99"] = float(pct["p99"])
    deg = doc.get("degraded", {})
    if "degraded_us_per_q" in deg:
        out["degraded.us_per_q"] = float(deg["degraded_us_per_q"])
    return out


def _extract_build(doc: dict) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for variant, row in (doc.get("largest_config", {})
                         .get("per_variant", {})).items():
        for key in ("host_total_s", "device_warm_total_s"):
            if key in row:
                out[f"build.{variant}.{key}"] = float(row[key])
    return out


def _extract_queries(doc: dict) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for cls, row in doc.get("classes", {}).items():
        for key in ("host_us_per_q", "device_us_per_q"):
            if key in row:
                out[f"queries.{cls}.{key}"] = float(row[key])
    return out


EXTRACTORS = {
    "BENCH_rangereach.json": _extract_rangereach,
    "BENCH_build.json": _extract_build,
    "BENCH_queries.json": _extract_queries,
}


def extract(bench: str, doc: dict) -> Dict[str, float]:
    """Flat latency metrics (lower is better) for one BENCH document."""
    fn = EXTRACTORS.get(os.path.basename(bench))
    if fn is None:
        raise ValueError(
            f"no extractor for {bench!r} (known: {sorted(EXTRACTORS)})")
    return fn(doc)


# ---------------------------------------------------------------- history

def load_history(path: str = HISTORY) -> List[dict]:
    if not os.path.exists(path):
        return []
    runs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                runs.append(json.loads(line))
    return runs


def append_history(path: str, bench: str, metrics: Dict[str, float],
                   label: str = "", t: Optional[float] = None) -> dict:
    run = {
        "schema_version": SCHEMA_VERSION,
        "t": time.time() if t is None else t,
        "bench": os.path.basename(bench),
        "label": label,
        "metrics": metrics,
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(run) + "\n")
    return run


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def baseline_for(history: List[dict], bench: str, metric: str,
                 n: int) -> Optional[float]:
    """Median of the metric over the last ``n`` history runs of this
    bench that recorded it (None: no baseline yet)."""
    bench = os.path.basename(bench)
    vals = [run["metrics"][metric] for run in history
            if run.get("bench") == bench and metric in run.get(
                "metrics", {})]
    if not vals:
        return None
    return _median([float(v) for v in vals[-n:]])


# -------------------------------------------------------------- invariants

#: History-free ratio ceilings — the structural perf claims the fused
#: serving path must hold on every run, regardless of machine speed:
#: per query class the device engine may not be slower than the host
#: descent (the paper's "device strictly fastest" claim; both numbers
#: come from the same process so machine noise cancels), and the
#: single-host cluster emulation may not cost more than 2x the
#: single-device engine.  (bench, gate name, numerator metric,
#: denominator metric, max ratio).
RATIO_GATES = (
    ("BENCH_queries.json", "reach.device_vs_host",
     "queries.reach.device_us_per_q", "queries.reach.host_us_per_q", 1.0),
    ("BENCH_queries.json", "count.device_vs_host",
     "queries.count.device_us_per_q", "queries.count.host_us_per_q", 1.0),
    ("BENCH_queries.json", "collect.device_vs_host",
     "queries.collect.device_us_per_q",
     "queries.collect.host_us_per_q", 1.0),
    ("BENCH_queries.json", "knn.device_vs_host",
     "queries.knn.device_us_per_q", "queries.knn.host_us_per_q", 1.0),
    # polygon serves through the two-phase scan (host point-in-polygon
    # epilogue) — narrower margin, so a little noise headroom
    ("BENCH_queries.json", "polygon.device_vs_host",
     "queries.polygon.device_us_per_q",
     "queries.polygon.host_us_per_q", 1.25),
    ("BENCH_rangereach.json", "device_vs_host",
     "engines.device", "engines.host", 1.0),
    ("BENCH_rangereach.json", "cluster_vs_device",
     "engines.cluster", "engines.device", 2.0),
)


def gate_rows(bench: str, metrics: Dict[str, float],
              slack: float = 0.0) -> List[dict]:
    """Evaluate the :data:`RATIO_GATES` for one bench over its
    extracted metrics; ``slack`` relaxes every ceiling by a relative
    fraction (for cross-machine CI)."""
    bench = os.path.basename(bench)
    rows = []
    for b, name, num, den, ceil in RATIO_GATES:
        if b != bench or num not in metrics or den not in metrics:
            continue
        d = metrics[den]
        ratio = metrics[num] / d if d > 0 else float("inf")
        limit = ceil * (1.0 + slack)
        rows.append({"gate": name, "numerator": metrics[num],
                     "denominator": d, "ratio": ratio, "limit": limit,
                     "verdict": OK if ratio <= limit else REGRESSED})
    return rows


def print_gates(bench: str, rows: List[dict]) -> None:
    if not rows:
        return
    name_w = max([len(r["gate"]) for r in rows] + [12])
    print(f"[regress] {os.path.basename(bench)} ratio gates")
    print(f"  {'gate':<{name_w}}  {'num':>12}  {'den':>12}  "
          f"{'ratio':>7}  {'limit':>6}  verdict")
    for r in rows:
        print(f"  {r['gate']:<{name_w}}  {r['numerator']:12.3f}  "
              f"{r['denominator']:12.3f}  {r['ratio']:7.2f}  "
              f"{r['limit']:6.2f}  {r['verdict']}")


# ---------------------------------------------------------------- compare

def compare(bench: str, metrics: Dict[str, float], history: List[dict],
            baseline_n: int = 5, tol: float = 0.25,
            metric_tol: Optional[Dict[str, float]] = None) -> List[dict]:
    """Per-metric verdict rows: current vs noise-aware baseline.

    A metric REGRESSES when ``current > baseline * (1 + tolerance)``;
    it is IMPROVED below ``baseline * (1 - tolerance)`` (informational),
    NEW without a baseline, and ok otherwise.
    """
    metric_tol = metric_tol or {}
    rows = []
    for name in sorted(metrics):
        cur = float(metrics[name])
        base = baseline_for(history, bench, name, baseline_n)
        t = float(metric_tol.get(name, tol))
        if base is None:
            verdict, ratio = NEW, None
        else:
            ratio = cur / base if base > 0 else float("inf")
            if cur > base * (1.0 + t):
                verdict = REGRESSED
            elif cur < base * (1.0 - t):
                verdict = IMPROVED
            else:
                verdict = OK
        rows.append({"metric": name, "current": cur, "baseline": base,
                     "ratio": ratio, "tolerance": t, "verdict": verdict})
    return rows


def print_table(bench: str, rows: List[dict]) -> None:
    name_w = max([len(r["metric"]) for r in rows] + [12])
    print(f"[regress] {os.path.basename(bench)}")
    print(f"  {'metric':<{name_w}}  {'current':>12}  {'baseline':>12}  "
          f"{'ratio':>7}  {'tol':>5}  verdict")
    for r in rows:
        base = "-" if r["baseline"] is None else f"{r['baseline']:12.3f}"
        ratio = "-" if r["ratio"] is None else f"{r['ratio']:7.2f}"
        print(f"  {r['metric']:<{name_w}}  {r['current']:12.3f}  "
              f"{base:>12}  {ratio:>7}  {r['tolerance']:5.2f}  "
              f"{r['verdict']}")


# ---------------------------------------------------------------- driver

def run_sentinel(bench_paths: List[str], history_path: str = HISTORY,
                 baseline_n: int = 5, tol: float = 0.25,
                 metric_tol: Optional[Dict[str, float]] = None,
                 append: bool = True, label: str = "",
                 gates: bool = True, gate_slack: float = 0.0) -> int:
    """Check every bench file against the history plus the history-free
    :data:`RATIO_GATES`, optionally append the runs, print verdict
    tables; returns the process exit code (1 when anything REGRESSED)."""
    history = load_history(history_path)
    regressed = []
    gated = []
    for path in bench_paths:
        with open(path) as f:
            doc = json.load(f)
        metrics = extract(path, doc)
        if not metrics:
            print(f"[regress] {os.path.basename(path)}: no tracked "
                  f"metrics — skipped")
            continue
        rows = compare(path, metrics, history, baseline_n=baseline_n,
                       tol=tol, metric_tol=metric_tol)
        print_table(path, rows)
        regressed += [r for r in rows if r["verdict"] == REGRESSED]
        if gates:
            grows = gate_rows(path, metrics, slack=gate_slack)
            print_gates(path, grows)
            gated += [r for r in grows if r["verdict"] == REGRESSED]
        if append:
            append_history(history_path, path, metrics, label=label)
    if regressed:
        print(f"[regress] FAIL: {len(regressed)} metric(s) regressed "
              f"past tolerance:")
        for r in regressed:
            print(f"  {r['metric']}: {r['current']:.3f} vs baseline "
                  f"{r['baseline']:.3f} (x{r['ratio']:.2f} > "
                  f"1+{r['tolerance']:.2f})")
    if gated:
        print(f"[regress] FAIL: {len(gated)} ratio gate(s) broken:")
        for r in gated:
            print(f"  {r['gate']}: {r['numerator']:.3f} / "
                  f"{r['denominator']:.3f} = x{r['ratio']:.2f} > "
                  f"{r['limit']:.2f}")
    if regressed or gated:
        return 1
    print(f"[regress] ok: no regressions past tolerance "
          f"({len(history)} historical runs consulted)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", action="append", default=None,
                    help="BENCH_*.json to check (repeatable; default: "
                         "every known BENCH file present in the repo "
                         "root)")
    ap.add_argument("--history", default=HISTORY,
                    help="history JSONL (append-only)")
    ap.add_argument("--baseline-n", type=int, default=5,
                    help="baseline = median of the last N runs")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="global relative tolerance (0.25 = fail past "
                         "+25%%)")
    ap.add_argument("--metric-tol", action="append", default=[],
                    metavar="NAME=FRAC",
                    help="per-metric tolerance override (repeatable)")
    ap.add_argument("--no-gates", action="store_true",
                    help="skip the history-free device-vs-host ratio "
                         "ceilings")
    ap.add_argument("--gate-slack", type=float, default=0.0,
                    help="relative slack on every ratio-gate ceiling "
                         "(0.1 = allow 10%% over)")
    ap.add_argument("--no-append", action="store_true",
                    help="check only — do not record this run")
    ap.add_argument("--no-check", action="store_true",
                    help="append only — seed/extend the history "
                         "without gating")
    ap.add_argument("--label", default="",
                    help="free-form run label recorded in the history "
                         "(e.g. ci / local / a git sha)")
    args = ap.parse_args(argv)

    benches = args.bench or [
        os.path.join(ROOT, b) for b in BENCHES
        if os.path.exists(os.path.join(ROOT, b))]
    if not benches:
        print("[regress] no BENCH_*.json found — run the perf benches "
              "first")
        return 2
    mtol = {}
    for spec in args.metric_tol:
        name, _, frac = spec.partition("=")
        if not frac:
            ap.error(f"--metric-tol wants NAME=FRAC, got {spec!r}")
        mtol[name] = float(frac)
    if args.no_check:
        for path in benches:
            with open(path) as f:
                metrics = extract(path, json.load(f))
            append_history(args.history, path, metrics, label=args.label)
            print(f"[regress] appended {os.path.basename(path)} "
                  f"({len(metrics)} metrics) to {args.history}")
        return 0
    return run_sentinel(benches, history_path=args.history,
                        baseline_n=args.baseline_n, tol=args.tol,
                        metric_tol=mtol, append=not args.no_append,
                        label=args.label, gates=not args.no_gates,
                        gate_slack=args.gate_slack)


if __name__ == "__main__":
    sys.exit(main())
