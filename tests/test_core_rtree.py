"""Packed R-tree forest: bulk load invariants + query engines vs brute
force, 2-D points and 3-D boxes (the 3DReach-Rev leaf type)."""

import numpy as np
from conftest import given, st

from repro.core import build_forest, query_host, query_host_collect
from repro.core import query_jax_wavefront
from repro.core.rtree import intersects


def brute(boxes, tree_of, tid, rect, dim):
    sel = tree_of == tid
    if not sel.any():
        return False
    return bool(intersects(boxes[sel], rect, dim).any())


@given(st.integers(0, 10_000), st.sampled_from([2, 3]),
       st.sampled_from([2, 4, 16]))
def test_forest_query_vs_brute(seed, dim, fanout):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 6))
    P = int(rng.integers(0, 120))
    lo = rng.random((P, dim)).astype(np.float32) * 10
    hi = lo + rng.random((P, dim)).astype(np.float32) * (
        0.0 if dim == 2 else 2.0)   # 2-D: points; 3-D: boxes
    boxes = np.concatenate([lo, hi], axis=1)
    tree_of = rng.integers(0, T, size=P)
    forest = build_forest(boxes, np.arange(P, dtype=np.int32), tree_of, T,
                          fanout=fanout)
    # forest structural invariants
    assert forest.n_trees == T
    assert (np.sort(forest.entry_ids) == np.arange(P)).all()
    B = 24
    tids = rng.integers(-1, T, size=B)
    c = rng.random((B, dim)).astype(np.float32) * 10
    r = rng.random((B, dim)).astype(np.float32) * 3
    rects = np.concatenate([c - r, c + r], axis=1)
    got = query_host(forest, tids, rects)
    want = np.array([
        t >= 0 and brute(boxes, tree_of, t, rect, dim)
        for t, rect in zip(tids, rects)
    ])
    assert (got == want).all()


def test_node_mbrs_contain_children():
    rng = np.random.default_rng(3)
    P, T = 300, 4
    pts = rng.random((P, 2)).astype(np.float32) * 50
    boxes = np.concatenate([pts, pts], axis=1)
    tree_of = rng.integers(0, T, size=P)
    f = build_forest(boxes, np.arange(P, dtype=np.int32), tree_of, T,
                     fanout=8)
    # leaf-level MBRs contain their points
    for t in range(T):
        s, e = f.entry_off[t], f.entry_off[t + 1]
        if s == e:
            continue
        n0s, n0e = f.tree_off[0][t], f.tree_off[0][t + 1]
        for j in range(n0e - n0s):
            cs = s + j * f.fanout
            ce = min(cs + f.fanout, e)
            mbr = f.level_mbr[0][n0s + j]
            assert (f.entries[cs:ce, :2] >= mbr[:2] - 1e-6).all()
            assert (f.entries[cs:ce, 2:] <= mbr[2:] + 1e-6).all()


@given(st.integers(0, 10_000))
def test_wavefront_engine_matches_host(seed):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 5))
    P = int(rng.integers(1, 150))
    pts = rng.random((P, 2)).astype(np.float32) * 10
    boxes = np.concatenate([pts, pts], axis=1)
    tree_of = rng.integers(0, T, size=P)
    forest = build_forest(boxes, np.arange(P, dtype=np.int32), tree_of, T)
    B = 16
    tids = rng.integers(-1, T, size=B)
    c = rng.random((B, 2)).astype(np.float32) * 10
    r = rng.random((B, 2)).astype(np.float32) * 3
    rects = np.concatenate([c - r, c + r], axis=1)
    host = query_host(forest, tids, rects)
    dev, ovf = query_jax_wavefront(forest, tids, rects, capacity=256)
    assert not ovf.any()
    assert (host == dev).all()


def test_collect_matches_scan():
    rng = np.random.default_rng(5)
    P = 100
    pts = rng.random((P, 2)).astype(np.float32)
    boxes = np.concatenate([pts, pts], axis=1)
    f = build_forest(boxes, np.arange(P, dtype=np.int32),
                     np.zeros(P, np.int64), 1)
    rect = np.array([0.2, 0.2, 0.6, 0.6], np.float32)
    got = set(query_host_collect(f, 0, rect).tolist())
    want = {
        i for i in range(P)
        if 0.2 <= pts[i, 0] <= 0.6 and 0.2 <= pts[i, 1] <= 0.6
    }
    assert got == want
