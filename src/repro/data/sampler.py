"""Layered neighbour sampler for sampled GNN training (minibatch_lg).

GraphSAGE-style fanout sampling over CSR adjacency: given seed nodes,
draw up to ``fanout[l]`` neighbours per node per layer, emitting a
per-layer edge list in *local* (block) indexing plus the global id map.
Produces static-shape blocks (padded with self-loops) so the jitted GNN
step never recompiles.

This IS part of the system (JAX has no graph samplers); it reuses the
same CSR machinery as the reachability core.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from ..core.graph import CSR


@dataclasses.dataclass
class SampledBlock:
    """One mini-batch: layered bipartite blocks, innermost first.

    node_ids:  (N,) global ids; the first ``n_seeds`` are the seeds.
    layers:    per layer (src_local, dst_local) edge arrays, where dst are
               positions < layer_n_dst[l] and src index into node_ids.
    """

    node_ids: np.ndarray
    n_seeds: int
    layers: List[Tuple[np.ndarray, np.ndarray]]

    @property
    def n_nodes(self) -> int:
        return len(self.node_ids)


def sample_blocks(
    csr: CSR,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng: np.random.Generator,
    pad_to: int | None = None,
) -> SampledBlock:
    """Sample a layered block; ``fanouts`` outermost-last (e.g. (15, 10))."""
    seeds = np.asarray(seeds, dtype=np.int64)
    frontier = seeds
    all_nodes = [seeds]
    layers: List[Tuple[np.ndarray, np.ndarray]] = []
    # map global -> local, built incrementally
    local = {int(v): i for i, v in enumerate(seeds)}

    for f in fanouts:
        srcs: List[np.ndarray] = []
        dsts: List[np.ndarray] = []
        new_nodes: List[int] = []
        for di, v in enumerate(frontier):
            nb = csr.neighbors(int(v))
            if len(nb) == 0:
                continue
            take = nb if len(nb) <= f else rng.choice(nb, size=f, replace=False)
            ls = np.empty(len(take), dtype=np.int64)
            for k, u in enumerate(take):
                ui = int(u)
                li = local.get(ui)
                if li is None:
                    li = len(local)
                    local[ui] = li
                    new_nodes.append(ui)
                ls[k] = li
            srcs.append(ls)
            dsts.append(np.full(len(take), local[int(v)], dtype=np.int64))
        src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
        dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
        layers.append((src, dst))
        if new_nodes:
            all_nodes.append(np.asarray(new_nodes, dtype=np.int64))
            frontier = np.asarray(new_nodes, dtype=np.int64)
        else:
            frontier = np.zeros(0, np.int64)

    node_ids = np.concatenate(all_nodes)
    blk = SampledBlock(node_ids=node_ids, n_seeds=len(seeds), layers=layers)
    if pad_to is not None:
        blk = pad_block(blk, pad_to)
    return blk


def pad_block(blk: SampledBlock, n_nodes: int) -> SampledBlock:
    """Pad to static shapes: nodes to ``n_nodes`` (repeat node 0), edges of
    each layer to the next power-of-two bucket (self-loop padding on a
    sacrificial node keeps segment sums exact)."""
    assert blk.n_nodes <= n_nodes, (blk.n_nodes, n_nodes)
    ids = np.zeros(n_nodes, dtype=np.int64)
    ids[: blk.n_nodes] = blk.node_ids
    layers = []
    for src, dst in blk.layers:
        m = len(src)
        cap = max(16, 1 << int(np.ceil(np.log2(max(m, 1)))))
        s = np.full(cap, n_nodes - 1, dtype=np.int64)
        d = np.full(cap, n_nodes - 1, dtype=np.int64)
        s[:m], d[:m] = src, dst
        layers.append((s, d))
    return SampledBlock(node_ids=ids, n_seeds=blk.n_seeds, layers=layers)
