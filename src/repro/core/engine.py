"""Device-resident RangeReach query engine (compile-once serving).

The paper's pitch is that a 2DReach query "reduces to a single 2D R-tree
lookup" — but a lookup that round-trips through host NumPy per batch
(pointer gather on CPU, forest re-transposed to SoA per call, every leaf
scanned) forfeits the reduction.  :class:`QueryEngine` uploads a built
:class:`~repro.core.two_d_reach.TwoDReachIndex` to the accelerator
**once** and answers ``query_batch`` entirely on device:

1. **fused pointer lookup** — vertex→tree inside the jit: a plain
   gather for the base/comp variants, or the Pointer variant's
   bit-vector + rank structure evaluated with an in-jit SWAR popcount;
   spatial-sink queries (Alg. 2's special case) fuse to a point-in-rect
   test in the same trace;
2. **hierarchical prune** — the Pallas ``prune_tiles`` kernel ANDs each
   query rect against internal-level tile MBRs (coarse gate + fine
   test, see :mod:`repro.kernels.range_query.descent`) to decide which
   leaf tiles each query tile actually needs;
3. **masked descent scan** — the scalar-prefetch ``descent_scan``
   kernel visits only the compacted candidate tiles, so work scales
   with the query's R-tree footprint instead of the arena size.

Batches are padded to power-of-two **buckets** (and the candidate
capacity K likewise), so the jit cache is keyed on a handful of shapes:
steady-state serving recompiles nothing and re-transposes nothing —
asserted by tests via jit cache-size introspection.  Exactness never
rests on the pruning: the scan kernel re-masks by arena slice and exact
box test, so the engine is bit-identical to the ``query_host`` oracle
(scanning an extra tile is an idempotent OR with no new hits).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.range_query.descent import (
    build_tile_pyramid,
    descent_scan_pallas,
    prune_tiles_pallas,
)
from ..kernels.range_query.kernel import TB, TP
from ..kernels.range_query.ops import forest_soa
from .two_d_reach import TwoDReachIndex


def _bucket(n: int, lo: int) -> int:
    """Smallest power-of-two >= max(n, lo) (lo itself a power of two)."""
    b = lo
    while b < n:
        b <<= 1
    return b


def _popcount32_jnp(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(jnp.int32)


class QueryEngine:
    """Compile-once device engine over a built ``TwoDReachIndex``.

    Parameters
    ----------
    index:     any 2DReach variant (``base`` / ``comp`` / ``pointer``).
    interpret: run the Pallas kernels in interpret mode; ``None`` picks
               real kernels on TPU and interpret elsewhere.
    """

    def __init__(self, index: TwoDReachIndex,
                 interpret: Optional[bool] = None):
        if not isinstance(index, TwoDReachIndex):
            raise TypeError(
                f"QueryEngine serves TwoDReachIndex, got {type(index).__name__}"
            )
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._interpret = bool(interpret)
        self.variant = index.variant
        self.dim = index.forest.dim
        dim = self.dim

        # ---- one-time upload -------------------------------------------
        esoa, off = forest_soa(index.forest)          # cached transposition
        fine, coarse, nt = build_tile_pyramid(esoa, dim)
        self.n_tiles = nt
        self._entries = jnp.asarray(esoa)
        self._fine = jnp.asarray(fine)
        self._coarse = jnp.asarray(coarse)
        self._entry_off = jnp.asarray(off, jnp.int32)  # (T+1,)
        self._coords = jnp.asarray(index.coords, jnp.float32)
        self._excluded = jnp.asarray(index.excluded)
        if self.variant == "pointer":
            self._vertex_comp = jnp.asarray(index.vertex_comp, jnp.int32)
            self._bits = jnp.asarray(index.bitrank.bits)
            self._rank = jnp.asarray(index.bitrank.rank, jnp.int32)
            self._tree_ptrs = jnp.asarray(index.tree_ptrs, jnp.int32)
            self._vertex_tree = None
        else:
            self._vertex_tree = jnp.asarray(index.vertex_tree, jnp.int32)

        self.stats: Dict[str, float] = {
            "uploads": 1, "batches": 0, "queries": 0,
            "tiles_scanned": 0, "tiles_grid": 0, "tiles_full_scan": 0,
        }
        self._prepare = jax.jit(self._make_prepare())
        self._scan = jax.jit(self._make_scan())

    # ------------------------------------------------------------------
    # jit closures (per-engine, so cache introspection is local)
    # ------------------------------------------------------------------

    def _lookup(self, us: jax.Array) -> jax.Array:
        """Fused vertex -> tree id (-1: excluded / no tree), in-jit."""
        if self.variant != "pointer":
            return self._vertex_tree[us]
        c = self._vertex_comp[us]
        ok = c >= 0
        cc = jnp.maximum(c, 0)
        w = cc // 32
        b = (cc % 32).astype(jnp.uint32)
        word = self._bits[w]
        member = ((word >> b) & np.uint32(1)) > 0
        below = word & ((np.uint32(1) << b) - np.uint32(1))
        rank = self._rank[w] + _popcount32_jnp(below)
        t = self._tree_ptrs[
            jnp.minimum(rank, self._tree_ptrs.shape[0] - 1)
        ]
        return jnp.where(ok & member, t, -1)

    def _make_prepare(self):
        dim = self.dim
        nt = self.n_tiles
        interpret = self._interpret

        def prepare(us, rects_soa):
            # us (Bb,) int32; rects_soa (2*dim, Bb) f32
            tid = self._lookup(us)
            exc = self._excluded[us]
            valid = (tid >= 0) & ~exc
            t = jnp.maximum(tid, 0)
            qs = jnp.where(valid, self._entry_off[t], 0)
            qe = jnp.where(valid, self._entry_off[t + 1], 0)
            # Alg. 2 spatial-query special case, fused: the vertex's own
            # point against the rect (same float32 comparisons as host)
            pt = self._coords[us]
            inr = jnp.ones(us.shape[0], dtype=bool)
            for a in range(dim):
                inr = inr & (pt[:, a] >= rects_soa[a])
                inr = inr & (pt[:, a] <= rects_soa[dim + a])
            forced = exc & inr
            mask = prune_tiles_pallas(
                self._fine, self._coarse, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )
            active = mask[:, :nt] > 0                       # (NB, NT)
            cnt = active.sum(axis=1).astype(jnp.int32)
            j = jnp.arange(nt, dtype=jnp.int32)
            order = jnp.argsort(
                jnp.where(active, j[None, :], nt + j[None, :]), axis=1
            ).astype(jnp.int32)
            last = order[
                jnp.arange(order.shape[0]), jnp.maximum(cnt - 1, 0)
            ]
            cand = jnp.where(j[None, :] < cnt[:, None], order, last[:, None])
            return forced, qs, qe, cand, cnt, cnt.max()

        return prepare

    def _make_scan(self):
        dim = self.dim
        interpret = self._interpret

        def scan(cand_k, rects_soa, qs, qe):
            return descent_scan_pallas(
                cand_k, self._entries, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )

        return scan

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------

    @property
    def n_compiles(self) -> int:
        """Distinct (bucketed) shapes traced so far — flat in steady
        state; tests assert it via this introspection hook."""
        return int(self._prepare._cache_size() + self._scan._cache_size())

    def query_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Batched RangeReach, same contract as ``TwoDReachIndex
        .query_batch`` (and bit-identical to it)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=bool)
        rects = np.asarray(rects, dtype=np.float32).reshape(B, 2 * self.dim)
        Bb = _bucket(B, TB)
        us_p = np.zeros(Bb, dtype=np.int32)
        us_p[:B] = us
        rsoa = np.empty((2 * self.dim, Bb), dtype=np.float32)
        # padding rects must miss every box regardless of data extent:
        # min=+inf / max=-inf fails both halves of the intersect test
        # (a finite 1.0/0.0 sentinel would phantom-hit tiles spanning it)
        rsoa[: self.dim] = np.inf
        rsoa[self.dim:] = -np.inf
        rsoa[:, :B] = rects.T
        rsoa_dev = jnp.asarray(rsoa)

        forced, qs, qe, cand, cnt, mx = self._prepare(
            jnp.asarray(us_p), rsoa_dev
        )
        kb = min(_bucket(max(int(mx), 1), 1), self.n_tiles)
        hit = self._scan(cand[:, :kb], rsoa_dev, qs, qe)

        self.stats["batches"] += 1
        self.stats["queries"] += B
        # tiles_scanned: live candidate tiles (pruning effectiveness);
        # tiles_grid: kernel grid steps incl. bucket padding (actual work
        # — padded steps repeat the last tile, so their DMA is elided)
        self.stats["tiles_scanned"] += int(np.asarray(cnt).sum())
        self.stats["tiles_grid"] += (Bb // TB) * kb
        self.stats["tiles_full_scan"] += (Bb // TB) * self.n_tiles
        out = np.asarray(hit).astype(bool) | np.asarray(forced)
        return out[:B]

    def query(self, u: int, rect) -> bool:
        return bool(self.query_batch(np.array([u]), np.array([rect]))[0])


def engine_for(index, interpret: Optional[bool] = None):
    """Memoised ``QueryEngine`` for a built 2DReach index (one upload per
    index instance); returns ``None`` for index types the device engine
    does not serve — callers fall back to the host path.  An explicit
    ``interpret`` that disagrees with the memoised engine's mode rebuilds
    rather than silently returning the wrong kernel mode."""
    if not isinstance(index, TwoDReachIndex):
        return None
    eng = getattr(index, "_device_engine", None)
    if eng is None or (
        interpret is not None and eng._interpret != bool(interpret)
    ):
        eng = QueryEngine(index, interpret=interpret)
        index._device_engine = eng
    return eng
