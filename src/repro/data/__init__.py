"""Data substrate: synthetic LBSN graphs, query workloads, training
pipelines, and the GraphSAGE-style neighbour sampler."""

from .lbsn import SPECS, LBSNSpec, dataset_stats, generate_lbsn
from .pipeline import ShardInfo, din_batches, lm_batches, molecule_batches
from .queries import (
    DEGREE_BUCKETS,
    DEGREE_DEFAULT,
    KNN_DEFAULT_K,
    POLYGON_EDGE_VALUES,
    POLYGON_EDGES_DEFAULT,
    REGION_EXTENT_DEFAULT,
    REGION_EXTENT_VALUES,
    SELECTIVITY_VALUES,
    STREAM_OP_KINDS,
    ZIPF_DEFAULT_S,
    apply_stream_op,
    knn_workload,
    polygon_workload,
    streaming_workload,
    workload,
    zipf_workload,
)
from .registry import dataset_names, get_dataset
from .sampler import SampledBlock, pad_block, sample_blocks
