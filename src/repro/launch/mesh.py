"""Production mesh definition.

v5e-256 pods: a (16, 16) = 256-chip single-pod mesh with (data, model)
axes, and the 2-pod production mesh (2, 16, 16) = 512 chips adding the
"pod" data-parallel axis (DCN between pods, ICI within).

Functions, not module-level constants — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax

from ..distributed.sharding import MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(multi_pod: bool = False) -> MeshAxes:
    return MeshAxes(
        data=("pod", "data") if multi_pod else ("data",), model="model"
    )


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): (1, n) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_shard_mesh(n_dev: int = None):
    """1-D query-serving mesh over the ``data`` axis.

    The cluster :class:`~repro.cluster.ShardedEngine` shards the 2DReach
    forest over this axis (``launch/serve.py --engine cluster``); index
    PartitionSpecs live in ``distributed.sharding.index_shard_specs``.
    ``n_dev`` defaults to every local device.
    """
    n = len(jax.devices()) if n_dev is None else int(n_dev)
    return jax.make_mesh((n,), ("data",))
