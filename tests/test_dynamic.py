"""DynamicIndex vs the BFS oracle on the mutated graph.

The acceptance core: >= 1000 randomized interleaved update/query steps
across the three 2DReach variants, answers identical to
``rangereach_oracle_batch`` on the materialised mutated graph, both
before and after a compaction swap.  Plus targeted tests for the overlay
pieces (staging R-tree, union-find merges, cache invalidation, op-log
replay around a background swap).
"""

import numpy as np
import pytest

from repro.core import (
    build_dynamic_index,
    make_graph,
    rangereach_oracle_batch,
)
from repro.data import apply_stream_op, streaming_workload
from repro.dynamic import NEVER, CompactionPolicy, DynamicIndex, UnionFind
from conftest import random_geosocial

VARIANTS = ("2dreach", "2dreach-comp", "2dreach-pointer")


class GraphMirror:
    """Independent record of the mutated graph for the oracle."""

    def __init__(self, g):
        self.edges = [tuple(e) for e in g.edges]
        self.coords = [tuple(c) for c in g.coords]
        self.mask = list(g.spatial_mask)

    @property
    def n(self):
        return len(self.mask)

    def apply(self, op):
        if op[0] == "add_edge":
            self.edges.append((op[1], op[2]))
        elif op[0] == "add_vertex":
            self.coords.append(op[1] or (0.0, 0.0))
            self.mask.append(op[1] is not None)
        else:
            self.coords[op[1]] = op[2]
            self.mask[op[1]] = True

    def graph(self):
        return make_graph(
            self.n,
            np.asarray(self.edges, dtype=np.int64).reshape(-1, 2),
            np.asarray(self.coords, dtype=np.float32),
            np.asarray(self.mask, dtype=bool),
        )


def _run_interleaved(variant, n_steps, seed, compact_at=None,
                     policy=NEVER, n=45, m=130):
    """Drive one DynamicIndex through a randomized stream, checking every
    query against the oracle; returns (steps_executed, dyn)."""
    rng = np.random.default_rng(seed)
    g = random_geosocial(rng, n, m)
    dyn = build_dynamic_index(g, variant, policy=policy)
    mirror = GraphMirror(g)
    steps = 0
    for step, op in enumerate(streaming_workload(
            g, n_steps=n_steps, seed=seed + 1,
            p_query=0.45, p_edge=0.3, p_vertex=0.13, p_spatial=0.12)):
        if op[0] == "query":
            u, rect = op[1], op[2]
            got = dyn.query(u, rect)
            want = bool(rangereach_oracle_batch(
                mirror.graph(), np.array([u]), np.array([rect]))[0])
            assert got == want, (variant, step, u, rect)
        else:
            apply_stream_op(dyn, op)
            mirror.apply(op)
        if compact_at is not None and step == compact_at:
            assert dyn.compact(background=False)
            assert dyn.overlay_size == 0
        steps += 1
    assert dyn.n_nodes == mirror.n
    return steps, dyn


@pytest.mark.parametrize("variant", VARIANTS)
def test_interleaved_updates_queries_vs_oracle(variant):
    """>= 1000 total steps across the three variants, with a mid-stream
    compaction swap — answers must match the oracle before and after."""
    total = 0
    for seed in (3, 11):
        steps, dyn = _run_interleaved(
            variant, n_steps=180, seed=seed, compact_at=90
        )
        total += steps
        assert dyn.stats["n_compactions"] == 1
    assert total >= 360  # x3 variants >= 1000 steps over the suite


@pytest.mark.parametrize("method", ("georeach", "3dreach", "3dreach-rev"))
def test_dynamic_wraps_baseline_methods(method):
    """The dynamic layer is method-agnostic: baselines work unmodified."""
    steps, _ = _run_interleaved(method, n_steps=80, seed=5, n=30, m=80)
    assert steps == 80


def test_policy_background_compaction_equivalence():
    """Policy-triggered background swaps with racing mutations never lose
    or double-apply an update."""
    rng = np.random.default_rng(23)
    g = random_geosocial(rng, 50, 150)
    policy = CompactionPolicy(max_overlay_edges=40, max_staged=None,
                              max_updates=None, background=True)
    dyn = build_dynamic_index(g, "2dreach-comp", policy=policy)
    mirror = GraphMirror(g)
    for op in streaming_workload(g, n_steps=300, seed=24, p_query=0.0,
                                 p_edge=0.6, p_vertex=0.2, p_spatial=0.2):
        apply_stream_op(dyn, op)
        mirror.apply(op)
    dyn.join_compaction()
    assert dyn.stats["n_compactions"] >= 1
    gm = mirror.graph()
    us = rng.integers(0, mirror.n, size=80)
    ext = gm.spatial_extent()
    cx = rng.random(80) * (ext[2] - ext[0]) + ext[0]
    cy = rng.random(80) * (ext[3] - ext[1]) + ext[1]
    rects = np.stack([cx - 20, cy - 20, cx + 20, cy + 20], 1).astype(np.float32)
    assert (dyn.query_batch(us, rects)
            == rangereach_oracle_batch(gm, us, rects)).all()
    # snapshot must equal the mirror graph exactly
    snap = dyn.snapshot_graph()
    assert snap.n_nodes == gm.n_nodes
    assert (snap.spatial_mask == gm.spatial_mask).all()
    assert np.allclose(snap.coords, gm.coords)


def test_concurrent_compaction_triggers_are_exclusive():
    """Racing compact() calls must never overlap builds: the loser's swap
    would replay a stale op-log tail and corrupt the index."""
    import threading

    rng = np.random.default_rng(77)
    g = random_geosocial(rng, 60, 200)
    dyn = build_dynamic_index(g, "2dreach-comp", policy=NEVER)
    mirror = GraphMirror(g)
    stop = threading.Event()

    def force_compactions():
        while not stop.is_set():
            dyn.compact(background=True)

    threads = [threading.Thread(target=force_compactions) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        for op in streaming_workload(g, n_steps=250, seed=78, p_query=0.0,
                                     p_edge=0.6, p_vertex=0.2, p_spatial=0.2):
            apply_stream_op(dyn, op)
            mirror.apply(op)
    finally:
        stop.set()
        for t in threads:
            t.join()
    dyn.join_compaction()
    assert dyn.n_nodes == mirror.n
    snap = dyn.snapshot_graph()
    gm = mirror.graph()
    assert snap.n_nodes == gm.n_nodes
    assert (snap.spatial_mask == gm.spatial_mask).all()
    assert snap.n_edges == gm.n_edges  # both deduped by make_graph
    us = rng.integers(0, mirror.n, size=60)
    ext = gm.spatial_extent()
    cx = rng.random(60) * (ext[2] - ext[0]) + ext[0]
    cy = rng.random(60) * (ext[3] - ext[1]) + ext[1]
    rects = np.stack([cx - 15, cy - 15, cx + 15, cy + 15], 1).astype(np.float32)
    assert (dyn.query_batch(us, rects)
            == rangereach_oracle_batch(gm, us, rects)).all()


def test_failed_background_build_latches_no_retry_storm():
    """A crashing background build must latch the error: no policy-driven
    rebuild storm, join raises, explicit compact() clears and retries."""
    rng = np.random.default_rng(91)
    g = random_geosocial(rng, 40, 120)
    policy = CompactionPolicy(max_overlay_edges=5, max_staged=None,
                              max_updates=None, background=True)
    dyn = build_dynamic_index(g, "2dreach-comp", policy=policy)
    boom = RuntimeError("simulated build OOM")

    def broken_build(snapshot):
        raise boom

    dyn._build_static = broken_build
    for i in range(20):
        dyn.add_edge(int(rng.integers(0, 40)), int(rng.integers(0, 40)))
    # wait for the first (and only) doomed build to finish
    dyn._compactor._thread.join(10)
    assert dyn.compaction_error is boom
    assert dyn.stats.get("n_compaction_failures") == 1  # no storm
    assert dyn.stats["n_compactions"] == 0
    with pytest.raises(RuntimeError, match="background compaction failed"):
        dyn.join_compaction()
    # overlay intact; queries still exact
    assert dyn.overlay_size == 20
    gm = dyn.snapshot_graph()
    us = rng.integers(0, 40, size=30)
    ext = gm.spatial_extent()
    cx = rng.random(30) * (ext[2] - ext[0]) + ext[0]
    cy = rng.random(30) * (ext[3] - ext[1]) + ext[1]
    rects = np.stack([cx - 10, cy - 10, cx + 10, cy + 10], 1).astype(np.float32)
    assert (dyn.query_batch(us, rects)
            == rangereach_oracle_batch(gm, us, rects)).all()
    # explicit compact() clears the latch and retries with a working build
    del dyn._build_static  # restore the class method
    assert dyn.compact(background=False)
    assert dyn.compaction_error is None
    assert dyn.stats["n_compactions"] == 1 and dyn.overlay_size == 0
    assert (dyn.query_batch(us, rects)
            == rangereach_oracle_batch(gm, us, rects)).all()


def test_scc_merge_via_delta_cycle():
    """A delta edge closing a cycle collapses components (DAGGER-style)
    and queries route through the merged group."""
    # chain a -> b -> c, venue v reachable from c only
    coords = np.zeros((4, 2), np.float32)
    coords[3] = (5.0, 5.0)
    sm = np.array([False, False, False, True])
    g = make_graph(4, np.array([[0, 1], [1, 2], [2, 3]]), coords, sm)
    dyn = build_dynamic_index(g, "2dreach-comp", policy=NEVER)
    rect = np.array([4.5, 4.5, 5.5, 5.5], np.float32)
    assert dyn.query(0, rect)
    assert not dyn.query(3, rect) or g.spatial_mask[3]  # v itself in R
    # close the cycle c -> a: {a, b, c} become one SCC
    dyn.add_edge(2, 0)
    assert dyn.stats["n_scc_merges"] >= 1
    for u in (0, 1, 2):
        assert dyn.query(u, rect)
    # a new vertex wired into the cycle joins the merged group
    w = dyn.add_vertex()
    dyn.add_edge(w, 0)
    dyn.add_edge(2, w)
    assert dyn.stats["n_scc_merges"] >= 2
    assert dyn.query(w, rect)


def test_new_vertex_and_checkin_paths():
    g = make_graph(3, np.array([[0, 1]]),
                   np.zeros((3, 2), np.float32), np.zeros(3, bool))
    dyn = build_dynamic_index(g, "2dreach", policy=NEVER)
    rect = np.array([0.5, 0.5, 1.5, 1.5], np.float32)
    assert not dyn.query(0, rect)
    # check-in on existing vertex 1: reachable from 0 via base edge
    dyn.add_spatial(1, (1.0, 1.0))
    assert dyn.query(0, rect)
    assert dyn.query(1, rect)          # staged query vertex sees itself
    assert not dyn.query(2, rect)
    # new spatial vertex reachable only via a delta edge
    v = dyn.add_vertex((1.2, 1.2))
    assert dyn.query(v, rect)          # its own coordinate
    assert not dyn.query(2, rect)
    dyn.add_edge(2, v)
    assert dyn.query(2, rect)
    # a plain new user vertex reaches through delta edges into the base
    u = dyn.add_vertex()
    assert not dyn.query(u, rect)
    dyn.add_edge(u, 0)
    assert dyn.query(u, rect)


def test_mutation_validation():
    g = make_graph(3, np.array([[0, 1]]),
                   np.zeros((3, 2), np.float32),
                   np.array([True, False, False]))
    dyn = build_dynamic_index(g, "2dreach-comp", policy=NEVER)
    with pytest.raises(IndexError):
        dyn.add_edge(0, 99)
    with pytest.raises(IndexError):
        dyn.add_spatial(99, (0, 0))
    with pytest.raises(ValueError):
        dyn.add_spatial(0, (1, 1))     # already spatial in the base
    dyn.add_spatial(1, (2.0, 2.0))
    with pytest.raises(ValueError):
        dyn.add_spatial(1, (3.0, 3.0))  # already staged
    with pytest.raises(IndexError):
        dyn.query(99, np.array([0, 0, 1, 1], np.float32))


def test_reach_cache_hit_and_invalidation():
    rng = np.random.default_rng(31)
    g = random_geosocial(rng, 40, 120)
    dyn = build_dynamic_index(g, "2dreach-comp", policy=NEVER)
    dyn.add_edge(0, 1)  # non-empty overlay => expansions run
    # an always-miss region: the base probe answers False, so the query
    # falls through to the overlay expansion (and memoises it)
    rect = np.array([500, 500, 501, 501], np.float32)
    dyn.query(2, rect)
    dyn.query(2, rect)
    assert dyn.stats["cache_hits"] >= 1
    before = dyn.stats["n_cache_invalidations"]
    dyn.add_edge(2, 3)  # must drop every memo covering vertex 2
    assert dyn.stats["n_cache_invalidations"] >= before


def test_compaction_policy_thresholds():
    p = CompactionPolicy(max_overlay_edges=10, max_staged=5, max_updates=100)
    assert not p.should_compact(9, 4, 99)
    assert p.should_compact(10, 0, 0)
    assert p.should_compact(0, 5, 0)
    assert p.should_compact(0, 0, 100)
    assert not NEVER.should_compact(10**9, 10**9, 10**9)


def test_union_find_groups():
    uf = UnionFind(4)
    assert uf.group(2) == [2]
    assert uf.union(0, 1)
    assert not uf.union(1, 0)
    assert sorted(uf.group(0)) == [0, 1]
    e = uf.add()
    assert uf.union(e, 0)
    assert sorted(uf.group(1)) == [0, 1, e]
    assert uf.find(e) == uf.find(0) == uf.find(1)


def test_dynamic_nbytes_reports_overlay():
    rng = np.random.default_rng(7)
    g = random_geosocial(rng, 40, 120)
    dyn = build_dynamic_index(g, "2dreach-pointer", policy=NEVER)
    nb0 = dyn.nbytes()
    assert nb0["total"] >= nb0["rtree"] + nb0["aux"]
    dyn.add_vertex((1.0, 1.0))
    dyn.add_edge(0, 1)
    nb1 = dyn.nbytes()
    assert nb1["overlay"] > nb0["overlay"]
