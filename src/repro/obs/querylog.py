"""Structured query log: the durable record of what was actually served.

Every served query can append one bounded-memory record — vertex class,
query class, log2 rect-area bucket, owning shard, latency, result
cardinality — the direct input for the planned result cache (cache key =
``(vertex_class, rect_bucket)``) and query-log-driven hot-shard
repartitioning (shard load = records per shard).  The log is a
ring buffer (oldest records drop once ``capacity`` is reached, with a
drop counter, never unbounded growth) plus always-cheap aggregate
counters that survive ring eviction; ``to_jsonl`` exports the retained
window for offline analysis.
"""

from __future__ import annotations

import collections
import json
import math
import threading
import time
from typing import Dict, Optional

import numpy as np

FIELDS = ("t", "query_class", "vertex_class", "rect_bucket", "shard",
          "latency_us", "cardinality")


def rect_bucket(rect) -> int:
    """log2 bucket of the rect's area — the workload-skew key.

    Degenerate (zero-area) rects bucket to -64; buckets clamp to
    [-63, 63] so the key space stays enumerable for cache sizing.
    """
    r = np.asarray(rect, dtype=np.float64).ravel()
    dim = len(r) // 2
    area = 1.0
    for a in range(dim):
        area *= max(float(r[dim + a] - r[a]), 0.0)
    if area <= 0.0:
        return -64
    return int(np.clip(math.floor(math.log2(area)), -63, 63))


def vertex_class_of(index_like, us) -> np.ndarray:
    """Coarse per-vertex classes from whatever the serving object
    exposes: ``sink`` (excluded spatial sink — Alg. 2's special case),
    ``user`` (routed through a tree probe), ``unknown`` otherwise."""
    us = np.asarray(us, dtype=np.int64)
    exc = getattr(index_like, "_excluded_host", None)
    if exc is None:
        exc = getattr(index_like, "excluded", None)
    if exc is None:
        return np.full(len(us), "unknown", dtype=object)
    out = np.full(len(us), "user", dtype=object)
    out[np.asarray(exc)[us]] = "sink"
    return out


class QueryLog:
    """Bounded ring of per-query records + eviction-proof aggregates."""

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=self.capacity)
        self.total = 0
        self.by_class: Dict[str, int] = {}
        self.by_shard: Dict[int, int] = {}

    def record(self, query_class: str, vertex_class: str, rect_b: int,
               shard: int, latency_s: float, cardinality: int,
               t: Optional[float] = None) -> None:
        rec = (t if t is not None else time.time(), query_class,
               vertex_class, int(rect_b), int(shard),
               float(latency_s) * 1e6, int(cardinality))
        with self._lock:
            self._ring.append(rec)
            self.total += 1
            self.by_class[query_class] = self.by_class.get(query_class, 0) + 1
            self.by_shard[rec[4]] = self.by_shard.get(rec[4], 0) + 1

    def record_batch(self, query_class: str, vertex_classes, rects,
                     shards, latencies_s, cardinalities) -> None:
        """Vectorised append for a served batch (one lock per record,
        shared wall timestamp)."""
        now = time.time()
        shards = np.asarray(shards)
        lats = np.asarray(latencies_s, dtype=np.float64)
        cards = np.asarray(cardinalities)
        for i in range(len(lats)):
            self.record(query_class, str(vertex_classes[i]),
                        rect_bucket(rects[i]), int(shards[i]),
                        float(lats[i]), int(cards[i]), t=now)

    # -- introspection --------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    @property
    def dropped(self) -> int:
        """Records evicted from the ring (aggregates still count them)."""
        with self._lock:
            return self.total - len(self._ring)

    def records(self) -> list:
        with self._lock:
            return list(self._ring)

    def snapshot(self) -> dict:
        with self._lock:
            n = len(self._ring)
            lat = np.fromiter((r[5] for r in self._ring), dtype=np.float64,
                              count=n)
            out = {
                "retained": n,
                "total": self.total,
                "dropped": self.total - n,
                "capacity": self.capacity,
                "by_class": dict(self.by_class),
                "by_shard": {str(k): v
                             for k, v in sorted(self.by_shard.items())},
            }
        if n:
            out["latency_us"] = {
                f"p{p}": float(np.percentile(lat, p)) for p in (50, 95, 99)}
        return out

    def to_jsonl(self, path: str) -> str:
        """Export the retained window, one JSON object per line."""
        with open(path, "w") as f:
            for rec in self.records():
                f.write(json.dumps(dict(zip(FIELDS, rec))) + "\n")
        return path

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.total = 0
            self.by_class = {}
            self.by_shard = {}


QUERY_LOG = QueryLog()
