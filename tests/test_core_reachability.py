"""Closure (Alg. 1) vs brute-force BFS; bit packing; device paths."""

import numpy as np
from conftest import given, st

from repro.core import closure_jax, closure_mbr_np, closure_np, condense
from repro.core import reachable_mask, scc_np
from repro.core.reachability import (
    nonzero_cols,
    pack_rows,
    row_popcount,
    unpack_rows,
)
from conftest import random_geosocial


@given(st.integers(0, 10_000))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    r = int(rng.integers(1, 8))
    p = int(rng.integers(1, 130))
    rows = rng.random((r, p)) < 0.3
    bits = pack_rows(rows)
    assert bits.shape == (r, (p + 31) // 32)
    assert (unpack_rows(bits, p) == rows).all()
    assert (row_popcount(bits) == rows.sum(1)).all()


@given(st.integers(0, 10_000))
def test_closure_matches_bfs(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 50))
    g = random_geosocial(rng, n, int(rng.integers(2, 4 * n)))
    labels = scc_np(n, g.edges)
    cond = condense(n, g.edges, labels)
    clo = closure_np(cond, n, g.spatial_ids)
    col_of = {int(v): i for i, v in enumerate(clo.spatial_vertex)}
    for u in range(0, n, max(1, n // 7)):
        want = {
            col_of[int(v)]
            for v in np.nonzero(reachable_mask(g, u) & g.spatial_mask)[0]
        }
        got = set(clo.comp_set_cols(int(cond.comp[u])).tolist())
        assert got == want, (u, got, want)


@given(st.integers(0, 10_000))
def test_closure_jax_matches_np(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 40))
    g = random_geosocial(rng, n, int(rng.integers(2, 3 * n)))
    cond = condense(n, g.edges, scc_np(n, g.edges))
    clo = closure_np(cond, n, g.spatial_ids)
    # dense boolean closure over ALL comps (own sets as bool rows)
    p = clo.p
    own = np.zeros((cond.n_comps, p), dtype=bool)
    for c in range(cond.n_comps):
        own[c, clo.own_cols[clo.own_indptr[c]:clo.own_indptr[c + 1]]] = True
    out = closure_jax(cond.n_comps, cond.dag_edges, own,
                      n_sweeps=cond.n_levels + 1)
    for c in range(cond.n_comps):
        assert (np.nonzero(out[c])[0] == clo.comp_set_cols(c)).all()


def test_mbr_closure():
    rng = np.random.default_rng(0)
    g = random_geosocial(rng, 40, 120)
    cond = condense(g.n_nodes, g.edges, scc_np(g.n_nodes, g.edges))
    clo = closure_np(cond, g.n_nodes, g.spatial_ids)
    mbr = closure_mbr_np(cond, g.coords, g.spatial_mask)
    for c in range(cond.n_comps):
        cols = clo.comp_set_cols(c)
        if len(cols) == 0:
            assert mbr[c, 0] > mbr[c, 2]  # empty box
        else:
            pts = g.coords[clo.spatial_vertex[cols]]
            np.testing.assert_allclose(
                mbr[c], [pts[:, 0].min(), pts[:, 1].min(),
                         pts[:, 0].max(), pts[:, 1].max()], rtol=1e-6)


def test_bitset_kernel_closure_matches():
    from repro.kernels.bitset_mm.ops import closure_fixpoint

    rng = np.random.default_rng(1)
    g = random_geosocial(rng, 35, 100)
    cond = condense(g.n_nodes, g.edges, scc_np(g.n_nodes, g.edges))
    clo = closure_np(cond, g.n_nodes, g.spatial_ids)
    d, p = cond.n_comps, clo.p
    own = np.zeros((d, p), dtype=bool)
    for c in range(d):
        own[c, clo.own_cols[clo.own_indptr[c]:clo.own_indptr[c + 1]]] = True
    A = np.zeros((d, d), dtype=bool)
    if cond.dag_edges.size:
        A[cond.dag_edges[:, 0], cond.dag_edges[:, 1]] = True
    for use_mxu in (False, True):
        got = closure_fixpoint(
            pack_rows(own), pack_rows(A), n_iters=cond.n_levels + 1,
            use_mxu=use_mxu)
        for c in range(d):
            assert (nonzero_cols(got[c], p) == clo.comp_set_cols(c)).all()
