"""3DReach and 3DReach-Rev baselines (Bouros et al., EDBT'25).

The paper compares against these, so they are implemented from scratch:

* **3DReach**: SCC condensation -> AIJ interval labels -> every spatial
  vertex indexed as the 3-D point ``(x, y, post(comp(v)))`` in ONE 3-D
  R-tree.  A query issues **one 3-D range probe per interval** of the
  query component's label — the multiplicity that makes its latency blow
  up on high-social-complexity graphs (paper Fig. 3, Yelp).
* **3DReach-Rev**: interval labels on the *reversed* condensation; a
  spatial vertex becomes one **vertical line segment** ``(x, y,
  [lo, hi])`` per reverse interval (so the index stores more/larger
  geometry — paper Table 4 shows ~2x size), and a query is a single 3-D
  probe at ``z = post_rev(comp(u))``.

Both reuse the packed R-tree forest (dim=3; segments are genuine boxes).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict

import numpy as np

from .condensation import Condensation, condense
from .graph import GeosocialGraph
from .interval_labels import IntervalLabels, build_interval_labels
from .rtree import DEFAULT_FANOUT, RTreeForest, build_forest, query_host
from .scc import scc_np


@dataclasses.dataclass
class ThreeDReachIndex:
    variant: str                 # "3d" | "3drev"
    n: int
    cond: Condensation
    labels: IntervalLabels       # forward labels (3d) or reverse (3drev)
    forest: RTreeForest          # single 3-D tree (tree id 0)
    stats: Dict[str, float]

    def nbytes_rtree(self) -> int:
        return self.forest.nbytes_total()

    def nbytes_labels(self) -> int:
        # 3DReach stores the labelling; 3DReach-Rev bakes it into geometry
        return self.labels.nbytes() if self.variant == "3d" else int(
            self.labels.post.nbytes
        )

    def nbytes_total(self) -> int:
        return self.nbytes_rtree() + self.nbytes_labels()

    def query_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        us = np.asarray(us, dtype=np.int64)
        rects = np.asarray(rects, dtype=np.float32).reshape(len(us), 4)
        c = self.cond.comp[us]
        if self.variant == "3d":
            # one 3-D probe per interval of the query component
            s = self.labels.indptr[c]
            e = self.labels.indptr[c + 1]
            cnt = (e - s).astype(np.int64)
            qi = np.repeat(np.arange(len(us)), cnt)
            slot = np.repeat(s, cnt) + _ragged_arange(cnt)
            lo = self.labels.lo[slot].astype(np.float32)
            hi = self.labels.hi[slot].astype(np.float32)
            r = rects[qi]
            rect3 = np.stack(
                [r[:, 0], r[:, 1], lo - 0.5, r[:, 2], r[:, 3], hi + 0.5],
                axis=1,
            )
            sub = query_host(
                self.forest, np.zeros(len(qi), dtype=np.int64), rect3
            )
            ans = np.zeros(len(us), dtype=bool)
            np.logical_or.at(ans, qi, sub)
            return ans
        # 3drev: single probe at z = post_rev(comp(u))
        z = self.labels.post[c].astype(np.float32)
        rect3 = np.stack(
            [rects[:, 0], rects[:, 1], z, rects[:, 2], rects[:, 3], z],
            axis=1,
        )
        return query_host(self.forest, np.zeros(len(us), dtype=np.int64), rect3)

    def query(self, u: int, rect) -> bool:
        return bool(self.query_batch(np.array([u]), np.array([rect]))[0])

    def intervals_per_query_comp(self, us: np.ndarray) -> np.ndarray:
        c = self.cond.comp[np.asarray(us, dtype=np.int64)]
        return (self.labels.indptr[c + 1] - self.labels.indptr[c]).astype(
            np.int64
        )


def build_3dreach(
    graph: GeosocialGraph,
    variant: str = "3d",
    fanout: int = DEFAULT_FANOUT,
) -> ThreeDReachIndex:
    assert variant in ("3d", "3drev")
    t_start = time.perf_counter()
    stats: Dict[str, float] = {}
    n = graph.n_nodes

    t0 = time.perf_counter()
    labels_v = scc_np(n, graph.edges)
    cond = condense(n, graph.edges, labels_v)
    stats["t_scc"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    if variant == "3d":
        lbl = build_interval_labels(cond)
    else:
        rev = Condensation(
            comp=cond.comp,
            n_comps=cond.n_comps,
            dag_edges=cond.dag_edges[:, ::-1] if cond.dag_edges.size
            else cond.dag_edges,
            level=cond.level,  # unused by labelling
            comp_sizes=cond.comp_sizes,
        )
        lbl = build_interval_labels(rev)
    stats["t_labels"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    sv = graph.spatial_ids
    pts = graph.coords[sv]
    c = cond.comp[sv]
    if variant == "3d":
        z = lbl.post[c].astype(np.float32)
        boxes = np.stack(
            [pts[:, 0], pts[:, 1], z, pts[:, 0], pts[:, 1], z], axis=1
        )
        ids = sv
    else:
        # one segment per (spatial vertex, reverse interval)
        s = lbl.indptr[c]
        e = lbl.indptr[c + 1]
        cnt = (e - s).astype(np.int64)
        vi = np.repeat(np.arange(len(sv)), cnt)
        slot = np.repeat(s, cnt) + _ragged_arange(cnt)
        lo = lbl.lo[slot].astype(np.float32)
        hi = lbl.hi[slot].astype(np.float32)
        p2 = pts[vi]
        boxes = np.stack(
            [p2[:, 0], p2[:, 1], lo, p2[:, 0], p2[:, 1], hi], axis=1
        )
        ids = sv[vi]
    ext = graph.spatial_extent()
    zmax = float(cond.n_comps)
    extent3 = np.array(
        [ext[0], ext[1], 0.0, ext[2], ext[3], zmax], dtype=np.float32
    )
    forest = build_forest(
        boxes,
        ids.astype(np.int32),
        np.zeros(len(boxes), dtype=np.int64),
        n_trees=1,
        fanout=fanout,
        extent=extent3,
    )
    stats["t_forest"] = time.perf_counter() - t0
    stats["t_total"] = time.perf_counter() - t_start
    stats["n_comps"] = float(cond.n_comps)
    stats["total_intervals"] = float(lbl.total_intervals)

    return ThreeDReachIndex(
        variant=variant, n=n, cond=cond, labels=lbl, forest=forest,
        stats=stats,
    )


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
